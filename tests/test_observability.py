"""EXPLAIN ANALYZE / observability subsystem: spans, metrics, profiles.

The contracts under test:
  * span tracer — nesting, thread-safety, near-zero no-op when disabled;
  * metrics registry — concurrent increments are exact;
  * TransferCounter — no torn counts under concurrent queries;
  * QueryProfile — versioned schema-stable JSON (golden key sets for
    Q1/Q6/Q13, monotonic timings, rows exact), per-operator times summing
    to <= total wall time, fused regions carrying HLO cost estimates;
  * overhead guard — analyze=False keeps the one-sync-per-query contract
    (the counter that proves profiling is opt-in);
  * row-exactness — analyze=True returns bit-identical results on all 22
    TPC-H + 15 ClickBench golden queries;
  * profile_diff — a synthetic slowdown makes the CLI exit nonzero and
    name the offending operator.
"""
import json
import subprocess
import sys
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import instrument
from repro.core.executor import SiriusEngine
from repro.data import clickbench as cb
from repro.data.tpch_queries import QUERIES
from repro.observability import (
    METRICS, MetricsRegistry, QueryProfile, SpanTracer, diff_profiles,
    validate_profile,
)
from repro.observability.profile import _OP_KEYS, _PIPELINE_KEYS, _TOP_KEYS

from conftest import USE_KERNELS, assert_tables_equal

CB_ROWS = 10_000


@pytest.fixture(scope="module")
def cb_engine():
    eng = SiriusEngine(use_kernels=USE_KERNELS)
    cb.load_into_engine(eng, cb.generate(CB_ROWS))
    return eng


@pytest.fixture(scope="module")
def cb_catalog():
    return cb.clickbench_catalog(CB_ROWS)


# ---------------------------------------------------------------------------
# span tracer
# ---------------------------------------------------------------------------


def test_span_tracer_nests_and_records():
    tr = SpanTracer()
    tr.enable()
    with tr.span("query", category="executor") as q:
        with tr.span("pipeline", category="executor") as p:
            p.set(rows=42)
        q.set(qid=6)
    done = tr.finished()
    names = [s.name for s in done]
    assert names == ["pipeline", "query"]          # children finish first
    pipeline, query = done
    assert pipeline.parent is query
    assert pipeline.attrs == {"rows": 42}
    assert query.attrs == {"qid": 6}
    assert pipeline.seconds >= 0 and query.seconds >= pipeline.seconds


def test_span_tracer_disabled_is_noop():
    tr = SpanTracer()                              # disabled by default
    with tr.span("x") as s:
        s.set(ignored=True)                        # must not raise
    assert tr.finished() == []


def test_span_tracer_thread_stacks_are_independent():
    tr = SpanTracer()
    tr.enable()
    errors = []

    def worker(i):
        try:
            with tr.span(f"w{i}"):
                with tr.span(f"w{i}-inner") as inner:
                    assert inner.parent.name == f"w{i}"
        except BaseException as e:                 # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert len(tr.finished()) == 16


# ---------------------------------------------------------------------------
# metrics registry + transfer counter thread safety
# ---------------------------------------------------------------------------


def test_metrics_registry_concurrent_increments_are_exact():
    reg = MetricsRegistry()
    n_threads, n_incs = 16, 500

    def worker():
        c = reg.counter("test.hits")
        for _ in range(n_incs):
            c.inc()
        reg.histogram("test.lat").observe(0.5)

    threads = [threading.Thread(target=worker) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    snap = reg.snapshot()
    assert snap["test.hits"] == n_threads * n_incs
    assert snap["test.lat.count"] == n_threads
    assert snap["test.lat.sum"] == pytest.approx(0.5 * n_threads)
    delta = MetricsRegistry.delta({"test.hits": 1000}, snap)
    assert delta["test.hits"] == n_threads * n_incs - 1000


def test_transfer_counter_concurrent_queries_no_torn_counts():
    """Concurrent device→host materializations must count exactly —
    a torn ``+= 1`` is the regression this test exists to catch."""
    arr = jnp.arange(16)
    n_threads, n_calls = 8, 200
    with instrument.track_transfers() as counter:
        def worker():
            for _ in range(n_calls):
                np.asarray(arr)

        threads = [threading.Thread(target=worker) for _ in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    assert counter.total == n_threads * n_calls
    assert counter.in_pipeline == 0


# ---------------------------------------------------------------------------
# QueryProfile schema goldens (Q1 / Q6 / Q13)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("qid", [1, 6, 13])
def test_profile_schema_golden(qid, tpch_engine):
    result = tpch_engine.execute(QUERIES[qid](), analyze=True,
                                 query_text=f"tpch q{qid}")
    prof = tpch_engine.last_profile
    d = prof.to_dict()
    assert validate_profile(d) == []
    # golden key sets — schema stability, not exact timings
    assert tuple(sorted(d)) == tuple(sorted(_TOP_KEYS))
    for p in d["pipelines"]:
        assert tuple(sorted(p)) == tuple(sorted(_PIPELINE_KEYS))
        for op in p["operators"]:
            assert tuple(sorted(op)) == tuple(sorted(_OP_KEYS))
    assert d["schema_version"] == 1
    # monotonic timings
    assert d["total_seconds"] > 0
    assert 0 <= d["compile_seconds"] <= d["total_seconds"]
    op_sum = sum(op["seconds"] for p in d["pipelines"]
                 for op in p["operators"])
    assert 0 < op_sum <= d["total_seconds"] * 1.001
    # the final sink's output cardinality is the query's result cardinality
    final_sink = d["pipelines"][-1]["operators"][-1]
    assert final_sink["rows_out"] == result.num_rows
    # per-query metrics deltas carry the schema-stable counter families
    for key in ("compiler.traces", "kernel.filter_hits",
                "kernel.expand_hits", "kernel.topk_hits",
                "plan_cache.hits", "plan_cache.replay_mismatches",
                "buffers.cold_copy_bytes", "executor.sync_barriers",
                "executor.scalar_syncs", "strings.host_passes"):
        assert key in d["metrics"], f"missing metric family {key}"


def test_profile_json_roundtrip(tpch_engine):
    tpch_engine.execute(QUERIES[6](), analyze=True)
    prof = tpch_engine.last_profile
    restored = QueryProfile.from_json(prof.to_json())
    assert restored.to_json() == prof.to_json()
    text = prof.pretty()
    assert "EXPLAIN ANALYZE" in text
    assert "pipeline 0" in text


def test_fused_region_reports_cost_estimates(tpch_engine):
    """Compiled regions must surface HLO cost analysis (est_flops /
    est_bytes) into their profile entry — the healed hlo_analysis wiring."""
    tpch_engine.execute(QUERIES[3]())              # warm/compile
    tpch_engine.execute(QUERIES[3](), analyze=True)
    d = tpch_engine.last_profile.to_dict()
    fused = [op for p in d["pipelines"] for op in p["operators"]
             if op["category"] == "fused"]
    assert fused, "expected at least one fused region in Q3's profile"
    costed = [op for op in fused if "est_flops" in op["attrs"]]
    assert costed, "no fused region reported est_flops"
    for op in costed:
        assert op["attrs"]["est_flops"] > 0
        assert op["attrs"]["est_bytes"] > 0


# ---------------------------------------------------------------------------
# overhead guard: analyze=False keeps one-sync-per-query
# ---------------------------------------------------------------------------


def test_default_path_adds_zero_extra_syncs(tpch_engine):
    plan = QUERIES[6]()
    tpch_engine.execute(plan)                      # warm: compile regions
    before = instrument.sync_barriers.value
    for _ in range(3):
        tpch_engine.execute(plan)
    assert instrument.sync_barriers.value - before == 3, \
        "analyze=False must issue exactly one barrier per query"
    # and the analyzed run of the same plan issues *more* (opt-in syncs)
    before = instrument.sync_barriers.value
    tpch_engine.execute(plan, analyze=True)
    assert instrument.sync_barriers.value - before > 1


# ---------------------------------------------------------------------------
# row-exactness: all 22 TPC-H + 15 ClickBench golden queries
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("qid", sorted(QUERIES))
def test_analyze_row_exact_tpch(qid, tpch_engine):
    plain = tpch_engine.execute(QUERIES[qid]()).to_host()
    analyzed = tpch_engine.execute(QUERIES[qid](), analyze=True).to_host()
    assert_tables_equal(analyzed, plain)
    assert validate_profile(tpch_engine.last_profile.to_dict()) == []


@pytest.mark.parametrize("qid", sorted(cb.CLICKBENCH_QUERIES))
def test_analyze_row_exact_clickbench(qid, cb_engine, cb_catalog):
    sql = cb.CLICKBENCH_QUERIES[qid]
    plain = cb_engine.sql(sql, catalog=cb_catalog).to_host()
    analyzed = cb_engine.sql(sql, catalog=cb_catalog, analyze=True).to_host()
    assert_tables_equal(analyzed, plain)
    assert validate_profile(cb_engine.last_profile.to_dict()) == []


# ---------------------------------------------------------------------------
# SQL frontend: EXPLAIN ANALYZE
# ---------------------------------------------------------------------------


Q6_SQL = ("select sum(l_extendedprice * l_discount) as revenue from lineitem "
          "where l_shipdate >= date '1994-01-01' "
          "and l_shipdate < date '1995-01-01' "
          "and l_discount between 0.05 and 0.07 and l_quantity < 24")


def test_explain_analyze_sql_returns_profile(tpch_engine):
    prof = tpch_engine.sql("EXPLAIN ANALYZE " + Q6_SQL)
    assert isinstance(prof, QueryProfile)
    assert prof is tpch_engine.last_profile
    assert validate_profile(prof.to_dict()) == []
    assert prof.query.startswith("select sum")
    # case-insensitive, whitespace-tolerant prefix
    prof2 = tpch_engine.sql("  explain   analyze " + Q6_SQL)
    assert isinstance(prof2, QueryProfile)


def test_run_sql_explain_analyze_requires_engine(tpch_db):
    from repro.sql import SqlError, run_sql
    with pytest.raises(SqlError, match="EXPLAIN ANALYZE"):
        run_sql("EXPLAIN ANALYZE " + Q6_SQL, tpch_db)


def test_sql_analyze_kwarg_returns_rows_and_profile(tpch_engine):
    out = tpch_engine.sql(Q6_SQL, analyze=True)
    ref = tpch_engine.sql(Q6_SQL)
    assert_tables_equal(out.to_host(), ref.to_host())
    assert isinstance(tpch_engine.last_profile, QueryProfile)


# ---------------------------------------------------------------------------
# profile diffing
# ---------------------------------------------------------------------------


def _mini_profile(sink_seconds: float) -> dict:
    return {
        "schema_version": 1, "query": "q", "engine": {},
        "total_seconds": 0.01 + sink_seconds, "compile_seconds": 0.0,
        "execute_seconds": 0.01 + sink_seconds,
        "pipelines": [{"pid": 0, "source": "scan:lineitem", "deps": [],
                       "operators": [
                           {"name": "scan:lineitem", "category": "scan",
                            "rows_in": 100, "rows_out": 100,
                            "seconds": 0.01, "attrs": {}},
                           {"name": "AggSink", "category": "groupby",
                            "rows_in": 100, "rows_out": 1,
                            "seconds": sink_seconds, "attrs": {}}]}],
        "operator_totals": {"scan": 0.01, "groupby": sink_seconds},
        "metrics": {}, "plan": "", "fragments": [],
    }


def test_diff_profiles_flags_synthetic_slowdown():
    old, new = _mini_profile(0.004), _mini_profile(0.100)
    assert validate_profile(old) == [] and validate_profile(new) == []
    regressions, report = diff_profiles(old, new)
    assert regressions, "25x sink slowdown must regress"
    assert any("AggSink" in r for r in regressions)
    # same profile → clean
    assert diff_profiles(old, old) == ([], [])


def test_profile_diff_cli_exits_nonzero_and_names_operator(tmp_path):
    old, new = _mini_profile(0.004), _mini_profile(0.100)
    pa, pb = tmp_path / "old.json", tmp_path / "new.json"
    pa.write_text(json.dumps(old))
    pb.write_text(json.dumps(new))
    proc = subprocess.run(
        [sys.executable, "scripts/profile_diff.py", str(pa), str(pb)],
        capture_output=True, text=True, timeout=120)
    assert proc.returncode == 1, proc.stdout + proc.stderr
    assert "REGRESSION" in proc.stdout and "AggSink" in proc.stdout
    clean = subprocess.run(
        [sys.executable, "scripts/profile_diff.py", str(pa), str(pa)],
        capture_output=True, text=True, timeout=120)
    assert clean.returncode == 0, clean.stdout + clean.stderr


def test_validate_profile_rejects_drift():
    d = _mini_profile(0.004)
    d["surprise"] = 1
    assert any("unknown top-level" in e for e in validate_profile(d))
    d2 = _mini_profile(0.004)
    del d2["metrics"]
    assert any("missing top-level" in e for e in validate_profile(d2))
    d3 = _mini_profile(0.004)
    d3["pipelines"][0]["operators"][0]["category"] = "mystery"
    assert any("unknown category" in e or "mystery" in e
               for e in validate_profile(d3))
    d4 = _mini_profile(0.004)
    d4["pipelines"][0]["operators"][0]["seconds"] = 99.0
    assert any("sum" in e for e in validate_profile(d4))


# ---------------------------------------------------------------------------
# hybrid accelerate(analyze=True)
# ---------------------------------------------------------------------------


def test_accelerate_analyze_merges_fragment_profiles(tpch_engine):
    from repro.sql import sql_to_wire
    wire = sql_to_wire(Q6_SQL)
    out = tpch_engine.accelerate(wire, analyze=True)
    prof = tpch_engine.last_profile
    assert isinstance(prof, QueryProfile)
    assert validate_profile(prof.to_dict()) == []
    assert prof.fragments, "accelerate profile must carry fragment entries"
    for frag in prof.fragments:
        assert "_profile" not in frag            # popped during the merge
        assert frag["seconds"] >= 0
    assert prof.engine.get("accelerate") is True
    assert out.num_rows == 1
