"""Per-kernel validation: sweep shapes/dtypes, assert_allclose vs ref.py.

Kernels run in interpret mode (CPU container); the pallas_call + BlockSpec
lowering path is identical to the TPU deployment path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
# groupby_agg
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [1, 17, 1024, 5000])
@pytest.mark.parametrize("n_groups", [1, 7, 200, 1000])
@pytest.mark.parametrize("v_cols", [1, 3])
def test_groupby_sum_shapes(n, n_groups, v_cols):
    rng = np.random.default_rng(n * 31 + n_groups)
    g = jnp.asarray(rng.integers(0, n_groups, n))
    v = jnp.asarray(rng.normal(size=(n, v_cols)))
    got = ops.groupby_sum(g, v, n_groups)
    want = ref.groupby_sum_ref(g, v, n_groups)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64, jnp.int32])
def test_groupby_sum_dtypes(dtype):
    rng = np.random.default_rng(7)
    g = jnp.asarray(rng.integers(0, 50, 2000))
    v = jnp.asarray(rng.integers(-100, 100, size=(2000, 2))).astype(dtype)
    got = ops.groupby_sum(g, v, 50)
    want = ref.groupby_sum_ref(g, v, 50)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_groupby_sum_invalid_rows_dropped():
    g = jnp.array([0, 1, 99999, -1, 1])
    v = jnp.ones((5, 1))
    got = ops.groupby_sum(g, v, 2)
    np.testing.assert_allclose(np.asarray(got).ravel(), [1.0, 2.0])


def test_groupby_sum_large_partitioned():
    rng = np.random.default_rng(11)
    n_groups = 10_000  # exceeds the VMEM group budget → multi-call partition
    g = jnp.asarray(rng.integers(0, n_groups, 20_000))
    v = jnp.asarray(rng.normal(size=(20_000, 1)))
    got = ops.groupby_sum_large(g, v, n_groups)
    want = ref.groupby_sum_ref(g, v, n_groups)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# filter_count
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [1, 100, 2048, 4097])
@pytest.mark.parametrize("c", [1, 2, 4])
def test_filter_mask_counts_shapes(n, c):
    rng = np.random.default_rng(n + c)
    cols = jnp.asarray(rng.normal(size=(n, c)).astype(np.float32))
    lo = jnp.asarray(rng.uniform(-1, 0, c).astype(np.float32))
    hi = jnp.asarray(rng.uniform(0, 1, c).astype(np.float32))
    m1, c1 = ops.filter_mask_counts(cols, lo, hi)
    m2, c2 = ref.filter_mask_counts_ref(cols, lo, hi)
    assert (np.asarray(m1) == np.asarray(m2)).all()
    assert (np.asarray(c1) == np.asarray(c2)).all()


def test_filter_select_compaction():
    cols = jnp.asarray(np.array([[0.1], [5.0], [0.2], [7.0], [0.3]],
                                np.float32))
    idx, count = ops.filter_select(cols, [0.0], [1.0])
    assert int(count) == 3
    assert sorted(np.asarray(idx)[:3].tolist()) == [0, 2, 4]


@given(st.integers(1, 3000), st.integers(0, 2**31))
@settings(max_examples=15, deadline=None)
def test_filter_property(n, seed):
    rng = np.random.default_rng(seed)
    cols = jnp.asarray(rng.normal(size=(n, 2)).astype(np.float32))
    lo = jnp.array([-0.5, -np.inf], jnp.float32)
    hi = jnp.array([0.5, 0.0], jnp.float32)
    m, _ = ops.filter_mask_counts(cols, lo, hi)
    want = (np.asarray(cols[:, 0]) >= -0.5) & (np.asarray(cols[:, 0]) <= 0.5) \
        & (np.asarray(cols[:, 1]) <= 0.0)
    assert (np.asarray(m) == want).all()


# ---------------------------------------------------------------------------
# hash_probe
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_build", [1, 64, 1000, 5000])
@pytest.mark.parametrize("n_probe", [1, 1024, 3000])
def test_hash_probe_shapes(n_build, n_probe):
    rng = np.random.default_rng(n_build + n_probe)
    bk = rng.choice(np.arange(10 * n_build + 10, dtype=np.int64), n_build,
                    replace=False)
    pk = np.concatenate([
        rng.choice(bk, max(n_probe // 2, 1)),
        rng.integers(10**7, 2 * 10**7, n_probe - max(n_probe // 2, 1)),
    ])[:n_probe]
    b32, p32 = ops.factorize_keys_int32(bk, pk)
    sk, sr, placed = ops.build_table32(jnp.asarray(b32))
    assert bool(placed)
    row, found = ops.hash_probe(jnp.asarray(p32), sk, sr)
    rrow, rfound = ref.hash_probe_ref(jnp.asarray(p32), sk, sr)
    assert (np.asarray(row) == np.asarray(rrow)).all()
    assert (np.asarray(found) == np.asarray(rfound)).all()
    # semantics
    exp = np.isin(pk, bk)
    assert (np.asarray(found) == exp).all()
    hit = np.asarray(found)
    assert (b32[np.asarray(row)[hit]] == p32[hit]).all()


@given(st.integers(1, 2000), st.integers(0, 2**31))
@settings(max_examples=15, deadline=None)
def test_hash_probe_property(n, seed):
    rng = np.random.default_rng(seed)
    bk = rng.choice(np.arange(4 * n, dtype=np.int64), n, replace=False)
    pk = rng.integers(0, 8 * n, 500)
    b32, p32 = ops.factorize_keys_int32(bk, pk)
    sk, sr, placed = ops.build_table32(jnp.asarray(b32))
    assert bool(placed)
    row, found = ops.hash_probe(jnp.asarray(p32), sk, sr)
    assert (np.asarray(found) == np.isin(pk, bk)).all()


# ---------------------------------------------------------------------------
# decode_attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("h,kvh", [(8, 8), (8, 4), (32, 8), (16, 1)])
@pytest.mark.parametrize("s", [64, 700, 1536])
def test_decode_attention_shapes(h, kvh, s):
    rng = np.random.default_rng(h * s)
    b, d = 2, 64
    q = jnp.asarray(rng.normal(size=(b, h, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, kvh, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, kvh, d)).astype(np.float32))
    lengths = jnp.asarray([s, max(s // 3, 1)])
    got = ops.decode_attention(q, k, v, lengths)
    want = ref.decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_dtypes(dtype):
    rng = np.random.default_rng(3)
    b, h, kvh, d, s = 1, 4, 2, 32, 300
    q = jnp.asarray(rng.normal(size=(b, h, d))).astype(dtype)
    k = jnp.asarray(rng.normal(size=(b, s, kvh, d))).astype(dtype)
    v = jnp.asarray(rng.normal(size=(b, s, kvh, d))).astype(dtype)
    lengths = jnp.asarray([s])
    got = ops.decode_attention(q, k, v, lengths).astype(jnp.float32)
    want = ref.decode_attention_ref(q, k, v, lengths).astype(jnp.float32)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


def test_decode_attention_ignores_padded_tail():
    """Entries beyond `length` must not affect the result."""
    rng = np.random.default_rng(5)
    b, h, kvh, d, s = 1, 4, 4, 32, 200
    q = jnp.asarray(rng.normal(size=(b, h, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, kvh, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, kvh, d)).astype(np.float32))
    out1 = ops.decode_attention(q, k, v, jnp.asarray([100]))
    k2 = k.at[:, 100:].set(99.0)
    v2 = v.at[:, 100:].set(-99.0)
    out2 = ops.decode_attention(q, k2, v2, jnp.asarray([100]))
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# join_expand (hash-join run expansion)
# ---------------------------------------------------------------------------


def _match_inputs(n_probe, n_build, key_range, seed):
    """(order, lo, counts) exactly as relational._join_match computes them."""
    rng = np.random.default_rng(seed)
    pk = rng.integers(0, key_range, n_probe)
    bk = rng.integers(0, key_range, n_build)
    order = np.argsort(bk, kind="stable")
    bs = bk[order]
    lo = np.searchsorted(bs, pk, side="left")
    counts = np.searchsorted(bs, pk, side="right") - lo
    return (jnp.asarray(order), jnp.asarray(lo), jnp.asarray(counts))


@pytest.mark.parametrize("n_probe,n_build,key_range", [
    (1, 1, 1),            # single row, guaranteed match
    (50, 30, 10),         # dense multi-match runs
    (500, 700, 2000),     # sparse: many zero-count probes
    (1500, 400, 40),      # output spans multiple TILE blocks
])
@pytest.mark.parametrize("how", ["inner", "left"])
def test_join_expand_matches_jnp_reference(n_probe, n_build, key_range, how):
    from repro.relational.join import _join_expand

    order, lo, counts = _match_inputs(n_probe, n_build, key_range,
                                      seed=n_probe + key_range)
    counts_out = jnp.maximum(counts, 1) if how == "left" else counts
    total = int(counts_out.sum())
    t_pad = ops.bucket_size(max(total, 1))
    got = ops.join_expand(order, lo, counts, counts_out, t_pad)
    want = _join_expand(order, lo, counts, counts_out, t_pad)
    for g, w, name in zip(got, want, ("probe_idx", "build_idx", "matched")):
        # tail past the true total is unspecified in both paths: compare
        # only the rows the caller keeps
        np.testing.assert_array_equal(np.asarray(g)[:total],
                                      np.asarray(w)[:total], err_msg=name)


def test_join_expand_no_matches():
    from repro.relational.join import _join_expand

    order = jnp.asarray(np.argsort([5, 6, 7], kind="stable"))
    lo = jnp.asarray(np.searchsorted([5, 6, 7], [0, 1, 2], side="left"))
    counts = jnp.zeros((3,), jnp.int64)
    counts_out = jnp.maximum(counts, 1)            # left join: passthrough
    t_pad = ops.bucket_size(3)
    got = ops.join_expand(order, lo, counts, counts_out, t_pad)
    want = _join_expand(order, lo, counts, counts_out, t_pad)
    np.testing.assert_array_equal(np.asarray(got[0])[:3],
                                  np.asarray(want[0])[:3])
    assert not np.asarray(got[2])[:3].any()


# ---------------------------------------------------------------------------
# topk_select (ORDER BY ... LIMIT)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [2, 1000, 1024, 3000])
@pytest.mark.parametrize("k", [1, 10, 128])
def test_topk_select_matches_stable_sort(n, k):
    if k > n:
        pytest.skip("k must not exceed n")
    rng = np.random.default_rng(n * 7 + k)
    # small integer range forces cross-block ties: the stability stressor
    keys = rng.integers(-50, 50, n).astype(np.float32)
    got = ops.topk_select(jnp.asarray(keys), k)
    want = np.argsort(keys, kind="stable")[:k]
    np.testing.assert_array_equal(np.asarray(got), want)


def test_topk_select_all_equal_keys_is_row_stable():
    keys = jnp.zeros((2500,), jnp.float32)
    got = ops.topk_select(keys, 16)
    np.testing.assert_array_equal(np.asarray(got), np.arange(16))


def test_backend_topk_routes_and_matches_sort():
    from repro.core.kernel_backend import KernelBackend
    from repro.relational.sort import SortKey, sort_table
    from repro.relational.table import Table

    rng = np.random.default_rng(13)
    t = Table.from_pydict({"a": rng.integers(0, 100, 5000),
                           "b": rng.normal(size=5000)})
    backend = KernelBackend(interpret=True)
    for ascending in (True, False):
        keys = [SortKey("a", ascending)]
        got = backend.try_topk(t, keys, 25)
        assert got is not None, "eligible top-k must route to the kernel"
        want = sort_table(t, keys, limit=25)
        for name in t.columns:
            np.testing.assert_allclose(np.asarray(got[name].data),
                                       np.asarray(want[name].data))
    assert backend.topk_hits == 2


def test_backend_topk_multikey_and_string_codes_match_sort():
    """Composite packing: mixed-direction multi-key (including dictionary
    codes, which the eager lexsort also compares as raw ints) is row-exact."""
    from repro.core.kernel_backend import KernelBackend
    from repro.relational.sort import SortKey, sort_table
    from repro.relational.table import STRING, Column, Table
    import jax.numpy as jnp

    rng = np.random.default_rng(29)
    n = 4000
    words = sorted({f"w{i:03d}" for i in range(40)})
    t = Table(
        {"c": Column(jnp.asarray(rng.integers(0, 50, n)), "numeric"),
         "s": Column(jnp.asarray(rng.integers(0, len(words), n)), STRING,
                     dictionary=list(words)),
         "x": Column(jnp.asarray(rng.normal(size=n)), "numeric")})
    backend = KernelBackend(interpret=True)
    cases = [
        [SortKey("c", False), SortKey("s", True)],      # desc count, asc word
        [SortKey("s", True), SortKey("c", True)],
        [SortKey("c", False), SortKey("s", False)],
    ]
    for keys in cases:
        got = backend.try_topk(t, keys, 10)
        assert got is not None, "multi-key int/dict sort must route"
        want = sort_table(t, keys, limit=10)
        for name in t.columns:
            np.testing.assert_allclose(np.asarray(got[name].data),
                                       np.asarray(want[name].data))
    assert backend.topk_hits == len(cases)
    # wide-range key blows the f32-exact composite bound: must decline
    wide = Table({"c": t["c"],
                  "big": Column(jnp.asarray(
                      rng.integers(0, 2**30, n)), "numeric")})
    assert backend.try_topk(wide, [SortKey("c"), SortKey("big")], 10) is None
