"""End-to-end TPC-H: accelerator engine vs independent numpy oracle.

This is the system-level behaviour test of the paper's single-node claim
surface: every one of the 22 queries must produce identical results on the
jnp pipeline engine and the pure-numpy fallback/reference engine.
"""
import numpy as np
import pytest

from repro.core.executor import SiriusEngine
from repro.core.fallback import FallbackEngine
from repro.core.plan import plan_equal, plan_from_json, plan_to_json
from repro.data.tpch_queries import QUERIES, SQL_QUERIES

from conftest import assert_tables_equal


@pytest.mark.parametrize("qid", sorted(QUERIES))
def test_query_matches_oracle(qid, tpch_engine, tpch_db):
    plan = QUERIES[qid]()
    res = tpch_engine.execute(plan).to_host()
    ref = FallbackEngine(tpch_db).execute(QUERIES[qid]())
    assert_tables_equal(res, ref)


@pytest.mark.parametrize("qid", sorted(QUERIES))
def test_plan_json_roundtrip(qid):
    plan = QUERIES[qid]()
    s = plan_to_json(plan)
    plan2 = plan_from_json(s)
    assert plan_to_json(plan2) == s


@pytest.mark.parametrize("qid", sorted(QUERIES))
def test_plan_json_roundtrip_structural(qid):
    """The Substrait wire format at query scale: decode(encode(plan)) must be
    structurally identical to the plan, not just re-serialize identically."""
    plan = QUERIES[qid]()
    restored = plan_from_json(plan_to_json(plan))
    assert plan_equal(restored, plan)
    assert plan_equal(plan, QUERIES[qid]())      # builders are deterministic


@pytest.mark.parametrize("qid", sorted(QUERIES))
def test_optimized_plan_json_roundtrip_structural(qid):
    """Optimizer output must survive the process boundary too — that is the
    handoff the paper's host-DB → engine split actually ships."""
    from repro.optimizer import optimize
    plan = optimize(QUERIES[qid]())
    restored = plan_from_json(plan_to_json(plan))
    assert plan_equal(restored, plan)


@pytest.mark.parametrize("qid", sorted(SQL_QUERIES))
def test_sql_plan_json_roundtrip_structural(qid):
    from repro.sql import sql_to_plan
    plan = sql_to_plan(SQL_QUERIES[qid])
    restored = plan_from_json(plan_to_json(plan))
    assert plan_equal(restored, plan)


def test_nonempty_results(tpch_engine):
    """Every query must return rows on the generated data (probes fire)."""
    for qid in sorted(QUERIES):
        res = tpch_engine.execute(QUERIES[qid]())
        assert res.num_rows > 0, f"Q{qid} returned no rows"


def test_morsel_driven_execution_matches(tpch_db):
    """Pipelines must be insensitive to morsel granularity (Q3, Q13)."""
    from repro.data.tpch import load_into_engine
    eng_small = SiriusEngine(morsel_rows=1000)
    load_into_engine(eng_small, tpch_db)
    for qid in (1, 3, 13):
        a = eng_small.execute(QUERIES[qid]()).to_host()
        b = FallbackEngine(tpch_db).execute(QUERIES[qid]())
        assert_tables_equal(a, b)


@pytest.mark.parametrize("qid", [1, 3, 5, 6, 10, 12, 19])
def test_kernel_backend_matches(qid, tpch_db):
    """Pallas operator backend (§3.2.2 'switch to custom kernels') must agree."""
    from repro.data.tpch import load_into_engine
    eng = SiriusEngine(use_kernels=True)
    load_into_engine(eng, tpch_db)
    res = eng.execute(QUERIES[qid]()).to_host()
    ref = FallbackEngine(tpch_db).execute(QUERIES[qid]())
    assert_tables_equal(res, ref)
    assert eng.backend.filter_hits + eng.backend.probe_hits > 0


def test_graceful_fallback(tpch_engine, tpch_db):
    """A plan referencing a missing table degrades to the host path (§3.2.2)."""
    from repro.core.plan import AggregateRel, ReadRel
    from repro.relational.aggregate import AggSpec
    from repro.relational.expressions import Col

    tpch_engine.host_tables["extra"] = {"x": np.arange(5.0)}
    plan = AggregateRel(ReadRel("extra"), [], [AggSpec("sum", Col("x"), "s")])
    res, path = tpch_engine.execute_with_fallback(plan)
    assert path == "fallback"
    assert float(np.asarray(res["s"])[0]) == 10.0
    assert tpch_engine.executor.fallback_queries >= 1
