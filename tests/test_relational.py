"""Unit + property tests for the relational substrate."""
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

import jax.numpy as jnp

from repro.relational import (
    AggSpec, Between, Case, Col, Column, DateLit, InList, Like, Lit, SortKey,
    Substr, Table, evaluate, group_aggregate, hash_join, sort_table,
)
from repro.relational.join import StaticHashTable, combine_keys
from repro.relational.table import date_to_days, days_to_date


# ---------------------------------------------------------------------------
# Table / Column
# ---------------------------------------------------------------------------


def test_string_dictionary_is_order_preserving():
    c = Column.from_strings(["pear", "apple", "pear", "banana"])
    assert list(c.dictionary) == ["apple", "banana", "pear"]
    assert list(np.asarray(c.data)) == [2, 0, 2, 1]
    assert list(c.to_host()) == ["pear", "apple", "pear", "banana"]


def test_date_roundtrip():
    assert days_to_date(date_to_days("1995-03-15")) == "1995-03-15"
    c = Column.from_dates(["1992-01-01", "1998-08-02"])
    assert c.to_host()[1] == np.datetime64("1998-08-02")


def test_recode_to_shared_dictionary():
    a = Column.from_strings(["x", "y", "z"])
    b = Column.from_strings(["y", "w"])
    from repro.relational.table import unify_string_keys
    a2, b2 = unify_string_keys(a, b)
    assert np.array_equal(a2.dictionary, b2.dictionary)
    assert list(a2.to_host()) == ["x", "y", "z"]
    assert list(b2.to_host()) == ["y", "w"]


def test_concat_merges_dictionaries():
    t1 = Table.from_pydict({"s": np.array(["a", "c"])})
    t2 = Table.from_pydict({"s": np.array(["b", "a"])})
    t = Table.concat([t1, t2])
    assert list(t["s"].to_host()) == ["a", "c", "b", "a"]


# ---------------------------------------------------------------------------
# expressions (property: engine eval == numpy semantics)
# ---------------------------------------------------------------------------


@given(st.lists(st.floats(-1e6, 1e6), min_size=1, max_size=50),
       st.floats(-1e6, 1e6))
@settings(max_examples=25, deadline=None)
def test_arith_and_compare_property(xs, threshold):
    arr = np.asarray(xs)
    t = Table.from_pydict({"x": arr})
    got = np.asarray(evaluate((Col("x") * Lit(2.0) + Lit(1.0)) > Lit(threshold), t).data)
    want = (arr * 2.0 + 1.0) > threshold
    assert (got == want).all()


@given(st.lists(st.sampled_from(["foo", "foobar", "bar", "baz", "qux"]),
                min_size=1, max_size=40))
@settings(max_examples=25, deadline=None)
def test_like_property(words):
    t = Table.from_pydict({"s": np.asarray(words)})
    got = np.asarray(evaluate(Like(Col("s"), "foo%"), t).data)
    want = np.array([w.startswith("foo") for w in words])
    assert (got == want).all()
    got2 = np.asarray(evaluate(Like(Col("s"), "%ba%"), t).data)
    want2 = np.array(["ba" in w for w in words])
    assert (got2 == want2).all()


def test_string_comparison_via_codes():
    t = Table.from_pydict({"s": np.array(["delta", "alpha", "zeta", "beta"])})
    got = np.asarray(evaluate(Col("s") < Lit("beta"), t).data)
    assert list(got) == [False, True, False, False]
    got = np.asarray(evaluate(Col("s") >= Lit("delta"), t).data)
    assert list(got) == [True, False, True, False]
    # literal absent from the dictionary
    got = np.asarray(evaluate(Col("s") <= Lit("charlie"), t).data)
    assert list(got) == [False, True, False, True]


def test_case_between_inlist_substr():
    t = Table.from_pydict({
        "x": np.array([1.0, 5.0, 10.0]),
        "p": np.array(["13-555", "99-123", "31-000"]),
    })
    c = evaluate(Case([(Col("x") > Lit(4.0), Lit(1.0))], Lit(0.0)), t)
    assert list(np.asarray(c.data)) == [0.0, 1.0, 1.0]
    b = evaluate(Between(Col("x"), Lit(2.0), Lit(9.0)), t)
    assert list(np.asarray(b.data)) == [False, True, False]
    i = evaluate(InList(Substr(Col("p"), 1, 2), ["13", "31"]), t)
    assert list(np.asarray(i.data)) == [True, False, True]


def test_extract_year():
    from repro.relational.expressions import ExtractYear
    t = Table.from_pydict({
        "d": np.array(["1992-01-01", "1995-06-17", "1998-12-31", "1996-02-29"],
                      dtype="datetime64[D]")})
    y = np.asarray(evaluate(ExtractYear(Col("d")), t).data)
    assert list(y) == [1992, 1995, 1998, 1996]


# ---------------------------------------------------------------------------
# Expr.__eq__ footgun (builds BinOp) vs the structural equals()/same() idiom
# ---------------------------------------------------------------------------


def test_expr_eq_overload_corrupts_list_operations():
    """Regression: ``==`` on Expr builds a BinOp (truthy), so list.remove /
    ``in`` match the *first* element, whatever it is."""
    a, b = Col("a"), Col("b")
    lst = [a, b]
    lst.remove(b)             # intends to drop b…
    assert lst == [b]         # …but dropped a: the footgun, pinned
    assert (Col("zzz") in [a, b]) is True   # membership is always True

    # the safe idioms
    assert a.equals(Col("a")) and not a.equals(b)
    assert a.same(Col("a"))
    kept = [e for e in [a, b] if not e.equals(b)]
    assert len(kept) == 1 and kept[0] is a
    assert a.equals(Col("x") + 1) is False
    assert (Col("x") + 1).equals(Col("x") + 1)


# ---------------------------------------------------------------------------
# joins
# ---------------------------------------------------------------------------


@given(st.integers(1, 200), st.integers(1, 100), st.integers(0, 2**31))
@settings(max_examples=20, deadline=None)
def test_inner_join_property(n_probe, n_build, seed):
    rng = np.random.default_rng(seed)
    bk = rng.choice(np.arange(n_build * 2), n_build, replace=False)
    pk = rng.choice(np.arange(n_build * 2), n_probe)
    probe = Table.from_pydict({"k": pk, "pv": np.arange(n_probe)})
    build = Table.from_pydict({"k": bk, "bv": np.arange(n_build) * 10})
    out = hash_join(probe, build, ["k"], ["k"], "inner").to_host()
    # oracle via python dict (build keys unique)
    lookup = {k: v for k, v in zip(bk, np.arange(n_build) * 10)}
    want = [(k, pv, lookup[k]) for k, pv in zip(pk, np.arange(n_probe)) if k in lookup]
    got = sorted(zip(out["k"], out["pv"], out["bv"]))
    assert got == sorted(want)


def test_multimatch_inner_join():
    probe = Table.from_pydict({"k": np.array([1, 2, 3])})
    build = Table.from_pydict({"k": np.array([1, 1, 2, 1]),
                               "v": np.array([10, 11, 12, 13])})
    out = hash_join(probe, build, ["k"], ["k"], "inner").to_host()
    assert sorted(zip(out["k"], out["v"])) == [(1, 10), (1, 11), (1, 13), (2, 12)]


def test_semi_anti_mark_left():
    probe = Table.from_pydict({"k": np.array([1, 2, 3, 4])})
    build = Table.from_pydict({"k": np.array([2, 4]), "v": np.array([20, 40])})
    assert list(hash_join(probe, build, ["k"], ["k"], "semi").to_host()["k"]) == [2, 4]
    assert list(hash_join(probe, build, ["k"], ["k"], "anti").to_host()["k"]) == [1, 3]
    m = hash_join(probe, build, ["k"], ["k"], "mark").to_host()
    assert list(m["__mark"]) == [False, True, False, True]
    l = hash_join(probe, build, ["k"], ["k"], "left").to_host()
    assert list(l["__matched"]) == [False, True, False, True]
    assert len(l["k"]) == 4


def test_multicolumn_join_keys():
    probe = Table.from_pydict({"a": np.array([1, 1, 2]), "b": np.array(["x", "y", "x"])})
    build = Table.from_pydict({"a": np.array([1, 2]), "b": np.array(["y", "x"]),
                               "v": np.array([7, 8])})
    out = hash_join(probe, build, ["a", "b"], ["a", "b"], "inner").to_host()
    assert sorted(zip(out["a"], out["v"])) == [(1, 7), (2, 8)]


# ---------------------------------------------------------------------------
# static hash table (oracle for the Pallas probe kernel)
# ---------------------------------------------------------------------------


@given(st.integers(1, 2000), st.integers(0, 2**31))
@settings(max_examples=20, deadline=None)
def test_static_hash_table_property(n, seed):
    rng = np.random.default_rng(seed)
    keys = rng.choice(np.arange(4 * n, dtype=np.int64), n, replace=False)
    ht = StaticHashTable.build(jnp.asarray(keys))
    assert bool(ht.all_placed)
    # every build key found, absent keys rejected
    probe = np.concatenate([keys, keys + 4 * n])
    row, found = ht.lookup(jnp.asarray(probe))
    assert np.asarray(found[:n]).all()
    assert not np.asarray(found[n:]).any()
    assert (keys[np.asarray(row[:n])] == keys).all()


# ---------------------------------------------------------------------------
# aggregate / sort
# ---------------------------------------------------------------------------


@given(st.integers(1, 300), st.integers(1, 10), st.integers(0, 2**31))
@settings(max_examples=20, deadline=None)
def test_group_aggregate_property(n, ngroups, seed):
    rng = np.random.default_rng(seed)
    g = rng.integers(0, ngroups, n)
    v = rng.normal(size=n)
    t = Table.from_pydict({"g": g, "v": v})
    out = group_aggregate(t, ["g"], [
        AggSpec("sum", Col("v"), "s"), AggSpec("min", Col("v"), "mn"),
        AggSpec("max", Col("v"), "mx"), AggSpec("avg", Col("v"), "av"),
        AggSpec("count_star", None, "n")]).to_host()
    for i, gid in enumerate(out["g"]):
        sel = v[g == gid]
        np.testing.assert_allclose(out["s"][i], sel.sum(), rtol=1e-9)
        np.testing.assert_allclose(out["mn"][i], sel.min())
        np.testing.assert_allclose(out["mx"][i], sel.max())
        np.testing.assert_allclose(out["av"][i], sel.mean(), rtol=1e-9)
        assert out["n"][i] == len(sel)


def test_count_distinct():
    t = Table.from_pydict({"g": np.array([0, 0, 0, 1, 1]),
                           "v": np.array([5, 5, 6, 7, 7])})
    out = group_aggregate(t, ["g"], [AggSpec("count_distinct", Col("v"), "cd")])
    assert list(out.to_host()["cd"]) == [2, 1]


def test_sort_multi_key_desc_and_strings():
    t = Table.from_pydict({
        "a": np.array([2, 1, 2, 1]),
        "s": np.array(["beta", "alpha", "alpha", "beta"]),
        "v": np.array([1.0, 2.0, 3.0, 4.0])})
    out = sort_table(t, [SortKey("a"), SortKey("s", ascending=False)]).to_host()
    assert list(out["v"]) == [4.0, 2.0, 1.0, 3.0]


def test_buffer_manager_spill_and_promote(tpch_db):
    from repro.buffer.manager import BufferManager
    from repro.relational.table import Table as T
    bm = BufferManager(caching_bytes=1 << 20)
    a = T.from_pydict({"x": np.arange(60_000, dtype=np.int64)})  # ~480KB
    b = T.from_pydict({"y": np.arange(60_000, dtype=np.int64)})
    c = T.from_pydict({"z": np.arange(60_000, dtype=np.int64)})
    bm.cache_table("a", a)
    bm.cache_table("b", b)
    bm.cache_table("c", c)          # evicts LRU ("a")
    assert bm.spill_count >= 1
    got = bm.get("a")               # transparently promoted back
    assert bm.promote_count >= 1
    assert int(np.asarray(got["x"].data)[-1]) == 59_999
