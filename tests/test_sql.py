"""SQL frontend: tokenizer/parser/binder/lowering + end-to-end TPC-H.

The acceptance surface of the drop-in claim: every SQL-text TPC-H query must
parse, optimize and execute row-for-row equal to its hand-built plan
counterpart on the numpy oracle engine, and the optimizer's predicate
pushdown must provably land at least one filter in a ReadRel (asserted via
``explain`` output).
"""
import numpy as np
import pytest

from repro.core.fallback import FallbackEngine
from repro.core.plan import ReadRel, explain, walk
from repro.data.tpch_queries import QUERIES, SQL_PUSHDOWN_QIDS, SQL_QUERIES
from repro.relational.expressions import BinOp, Col, InList, Like, Lit
from repro.sql import SqlError, parse_sql, run_sql, sql_to_plan, tokenize
from repro.sql.nodes import SqlCol, SqlExists, SqlFunc

from conftest import assert_tables_equal


# ---------------------------------------------------------------------------
# lexer / parser units
# ---------------------------------------------------------------------------


def test_tokenize_basics():
    toks = tokenize("select a, 'it''s' , 1.5 <= x -- comment\nfrom t")
    kinds = [(t.kind, t.value) for t in toks[:-1]]
    assert ("kw", "select") in kinds
    assert ("str", "it's") in kinds
    assert ("num", 1.5) in kinds
    assert ("op", "<=") in kinds
    assert all(v != "comment" for _, v in kinds)


def test_parse_precedence_and_shapes():
    stmt = parse_sql("select a + b * 2 from lineitem where x = 1 or y = 2 "
                     "and z = 3")
    item = stmt.items[0].expr
    assert isinstance(item, BinOp) and item.op == "+"          # * binds tighter
    assert isinstance(item.right, BinOp) and item.right.op == "*"
    w = stmt.where
    assert isinstance(w, BinOp) and w.op == "or"               # and over or
    assert isinstance(w.right, BinOp) and w.right.op == "and"


def test_parse_predicates():
    stmt = parse_sql(
        "select * from t where a between 1 and 2 and b not in (1, 2) "
        "and c like 'x%' and d not like '%y' and not exists "
        "(select * from u where u1 = a)")
    conjs = []

    def flat(e):
        if isinstance(e, BinOp) and e.op == "and":
            flat(e.left)
            flat(e.right)
        else:
            conjs.append(e)
    flat(stmt.where)
    assert any(isinstance(c, InList) and c.negate for c in conjs)
    assert any(isinstance(c, Like) and not c.negate for c in conjs)
    assert any(isinstance(c, Like) and c.negate for c in conjs)
    assert any(isinstance(c, SqlExists) and c.negate for c in conjs)


def test_parse_agg_and_case():
    stmt = parse_sql("select count(*) c, sum(case when x > 0 then 1 else 0 "
                     "end) s from t group by g order by c desc limit 5")
    assert isinstance(stmt.items[0].expr, SqlFunc)
    assert stmt.items[0].expr.arg is None
    assert stmt.order_by[0].ascending is False
    assert stmt.limit == 5


def test_parse_errors_have_position():
    with pytest.raises(SqlError) as ei:
        parse_sql("select from t")
    assert "^" in str(ei.value)
    with pytest.raises(SqlError):
        parse_sql("select a from t where")
    with pytest.raises(SqlError):
        parse_sql("select a from t limit 1.5")


def test_qualified_and_bare_columns():
    stmt = parse_sql("select o.o_orderkey, l_quantity from orders o, "
                     "lineitem where o.o_orderkey = l_orderkey")
    e = stmt.items[0].expr
    assert isinstance(e, SqlCol) and e.qualifier == "o"


# ---------------------------------------------------------------------------
# binder / lowering units
# ---------------------------------------------------------------------------


def test_bind_unknown_table_and_column():
    with pytest.raises(SqlError, match="unknown table"):
        sql_to_plan("select x from nosuch")
    with pytest.raises(SqlError, match="unknown column"):
        sql_to_plan("select nope from lineitem")
    # self-joins need distinguishing aliases; without them the scope rejects
    with pytest.raises(SqlError, match="duplicate table alias"):
        sql_to_plan("select n_name from nation, nation")


def test_self_join_with_aliases(tpch_db):
    """Aliased self-joins resolve through per-binding effective names."""
    out = run_sql(
        "select n1.n_name as a, n2.n_name as b "
        "from nation n1, nation n2, region "
        "where n1.n_regionkey = r_regionkey and n2.n_regionkey = r_regionkey "
        "and r_name = 'AMERICA' and n1.n_name < n2.n_name "
        "order by a, b", tpch_db)
    assert len(out["a"]) == 10          # C(5,2) pairs of AMERICA nations
    assert (np.asarray(out["a"], "U") < np.asarray(out["b"], "U")).all()
    # unqualified references to self-joined columns are ambiguous
    with pytest.raises(SqlError, match="ambiguous column"):
        sql_to_plan("select n_name from nation n1, nation n2, region "
                    "where n1.n_regionkey = r_regionkey "
                    "and n2.n_regionkey = r_regionkey")


def test_derived_table_requires_alias():
    with pytest.raises(SqlError, match="alias"):
        sql_to_plan("select c from (select count(*) as c from nation)")


def test_derived_table_two_level_aggregate(tpch_db):
    out = run_sql(
        "select cnt, count(*) as n_regions "
        "from (select r_regionkey, count(*) as cnt from nation, region "
        "      where n_regionkey = r_regionkey group by r_regionkey) "
        "     as per_region "
        "group by cnt order by cnt", tpch_db)
    assert int(sum(out["n_regions"])) == 5      # 5 regions partition 25 nations


def test_left_join_lowering_and_count_rewrite(tpch_db):
    """LEFT JOIN + count(build col) counts matches (0 for unmatched)."""
    from repro.core.plan import JoinRel
    plan = sql_to_plan(
        "select c_custkey, count(o_orderkey) as n "
        "from customer left outer join orders on c_custkey = o_custkey "
        "group by c_custkey", optimize=False)
    joins = [r for r in walk(plan) if isinstance(r, JoinRel)]
    assert len(joins) == 1 and joins[0].how == "left"
    out = run_sql(
        "select c_custkey, count(o_orderkey) as n "
        "from customer left outer join orders on c_custkey = o_custkey "
        "group by c_custkey order by c_custkey", tpch_db)
    # every customer appears exactly once, and the counts total the orders
    assert len(out["c_custkey"]) == len(tpch_db["customer"]["c_custkey"])
    assert int(np.sum(out["n"])) == len(tpch_db["orders"]["o_orderkey"])
    # spec rule: custkey % 3 == 0 customers have no orders → count 0
    zero = np.asarray(out["c_custkey"])[np.asarray(out["n"]) == 0]
    assert (zero % 3 == 0).all() and len(zero) > 0


def test_bind_date_coercion_and_interval():
    plan = sql_to_plan("select l_orderkey from lineitem "
                       "where l_shipdate < '1995-03-15'", optimize=False)
    lits = [n for r in walk(plan) for n in _walk_filter_lits(r)]
    assert any(l.kind == "date" for l in lits)
    a = sql_to_plan("select o_orderkey from orders where "
                    "o_orderdate < date '1993-10-01' + interval '3' month",
                    optimize=False)
    b = sql_to_plan("select o_orderkey from orders where "
                    "o_orderdate < date '1994-01-01'", optimize=False)
    from repro.core.plan import plan_equal
    assert plan_equal(a, b)


def _walk_filter_lits(rel):
    from repro.core.plan import FilterRel
    from repro.relational.expressions import walk_expr
    if isinstance(rel, FilterRel):
        return [n for n in walk_expr(rel.condition) if isinstance(n, Lit)]
    return []


def test_disconnected_join_graph_rejected():
    with pytest.raises(SqlError, match="disconnected"):
        sql_to_plan("select n_name from nation, region where n_name = 'X'")


def test_semi_join_from_in_subquery():
    plan = sql_to_plan(
        "select o_orderpriority from orders where o_orderkey in "
        "(select l_orderkey from lineitem)", optimize=False)
    from repro.core.plan import JoinRel
    joins = [r for r in walk(plan) if isinstance(r, JoinRel)]
    assert len(joins) == 1 and joins[0].how == "semi"
    assert joins[0].probe_keys == ["o_orderkey"]
    assert joins[0].build_keys == ["l_orderkey"]


def test_anti_join_from_not_exists():
    plan = sql_to_plan(
        "select c_name from customer where not exists "
        "(select * from orders where o_custkey = c_custkey)",
        optimize=False)
    from repro.core.plan import JoinRel
    joins = [r for r in walk(plan) if isinstance(r, JoinRel)]
    assert len(joins) == 1 and joins[0].how == "anti"
    assert joins[0].probe_keys == ["c_custkey"]


def test_left_join_build_columns_guarded():
    """Unmatched left-join rows carry no build values: only count(col) may
    consume them — anything else must be rejected, not mis-answered."""
    with pytest.raises(SqlError, match="LEFT JOIN"):
        sql_to_plan("select c_custkey, sum(o_totalprice) as s "
                    "from customer left outer join orders "
                    "on c_custkey = o_custkey group by c_custkey")
    with pytest.raises(SqlError, match="LEFT JOIN"):
        sql_to_plan("select c_custkey, o_orderkey "
                    "from customer left outer join orders "
                    "on c_custkey = o_custkey")
    with pytest.raises(SqlError, match="LEFT JOIN"):
        sql_to_plan("select c_custkey from customer left outer join orders "
                    "on c_custkey = o_custkey where o_totalprice > 0")
    # engines share one __matched marker: a second LEFT JOIN would clobber it
    with pytest.raises(SqlError, match="at most one LEFT JOIN"):
        sql_to_plan("select c_custkey from customer "
                    "left outer join orders on c_custkey = o_custkey "
                    "left outer join nation on c_nationkey = n_nationkey")


def test_engine_reregister_drops_stale_dictionaries(tpch_db):
    from repro.core.executor import SiriusEngine
    from repro.relational.table import Table

    eng = SiriusEngine()
    eng.register("t", Table.from_pydict({"s": np.array(["a", "b"]),
                                         "k": np.array([1, 2])}))
    assert "t" in eng.table_dictionaries
    eng.register("t", Table.from_pydict({"k": np.array([1, 2, 3])}))
    assert "t" not in eng.table_dictionaries


def test_correlated_scalar_subquery_decorrelates(tpch_db):
    """A correlated scalar comparison lowers to an aggregate grouped by the
    correlation key + an inner join — and computes the right answer."""
    from repro.core.plan import AggregateRel, JoinRel
    sql = ("select c_custkey from customer where c_acctbal > "
           "(select min(o_totalprice) from orders "
           "where o_custkey = c_custkey) order by c_custkey")
    plan = sql_to_plan(sql, optimize=False)
    joins = [r for r in walk(plan) if isinstance(r, JoinRel)]
    aggs = [r for r in walk(plan) if isinstance(r, AggregateRel)]
    assert any(j.how == "inner" for j in joins)
    assert any(a.group_keys == ["o_custkey"] for a in aggs)

    got = np.asarray(run_sql(sql, tpch_db)["c_custkey"])
    # independent numpy oracle for the correlated semantics
    orders, cust = tpch_db["orders"], tpch_db["customer"]
    keys, inv = np.unique(orders["o_custkey"], return_inverse=True)
    mins = np.full(len(keys), np.inf)
    np.minimum.at(mins, inv, orders["o_totalprice"])
    mn = dict(zip(keys, mins))
    want = np.array(sorted(
        ck for ck, bal in zip(cust["c_custkey"], cust["c_acctbal"])
        if ck in mn and bal > mn[ck]))
    assert len(want) > 0 and (got == want).all()


# ---------------------------------------------------------------------------
# end-to-end: SQL text vs hand-built plans on the numpy oracle
# ---------------------------------------------------------------------------


@pytest.fixture(scope="session")
def oracle(tpch_db):
    return FallbackEngine(tpch_db)


@pytest.mark.parametrize("qid", sorted(SQL_QUERIES))
def test_sql_matches_handbuilt_naive(qid, oracle):
    """The naive (unoptimized) lowering is already semantically right."""
    ref = oracle.execute(QUERIES[qid]())
    got = oracle.execute(sql_to_plan(SQL_QUERIES[qid], optimize=False))
    assert_tables_equal(got, ref)


@pytest.mark.parametrize("qid", sorted(SQL_QUERIES))
def test_sql_matches_handbuilt_optimized(qid, oracle):
    """parse → optimize → execute equals the hand-built plan row-for-row."""
    ref = oracle.execute(QUERIES[qid]())
    got = oracle.execute(sql_to_plan(SQL_QUERIES[qid], optimize=True))
    assert_tables_equal(got, ref)


@pytest.mark.parametrize("qid", SQL_PUSHDOWN_QIDS)
def test_pushdown_lands_in_readrel(qid):
    """Predicate pushdown provably moves ≥1 filter into a ReadRel, asserted
    both structurally and via the EXPLAIN output."""
    naive = sql_to_plan(SQL_QUERIES[qid], optimize=False)
    opt = sql_to_plan(SQL_QUERIES[qid], optimize=True)
    naive_scans = [r for r in walk(naive)
                   if isinstance(r, ReadRel) and r.filter is not None]
    opt_scans = [r for r in walk(opt)
                 if isinstance(r, ReadRel) and r.filter is not None]
    assert not naive_scans, "lowering must not pre-push filters"
    assert opt_scans, f"Q{qid}: no filter reached any ReadRel"
    assert "filter=" not in explain(naive)
    assert explain(opt).count("filter=") >= 1


@pytest.mark.parametrize("qid", sorted(SQL_QUERIES))
def test_sql_on_accelerator_engine(qid, tpch_engine, oracle):
    """run_sql through the jnp pipeline engine agrees with the oracle —
    for all 22 queries (the acceptance surface of the SQL frontend)."""
    ref = oracle.execute(QUERIES[qid]())
    got = run_sql(SQL_QUERIES[qid], tpch_engine).to_host()
    assert_tables_equal(got, ref)


def test_run_sql_on_host_dict(tpch_db):
    out = run_sql("select count(*) as n from nation", tpch_db)
    assert int(out["n"][0]) == 25


def test_run_sql_adhoc_query(tpch_db):
    """A query no hand-built plan memorizes — the point of the frontend."""
    out = run_sql(
        "select n_name, count(*) as suppliers, sum(s_acctbal) as total "
        "from supplier, nation where s_nationkey = n_nationkey "
        "and s_acctbal > 0 group by n_name "
        "order by total desc limit 5", tpch_db)
    assert len(out["n_name"]) == 5
    totals = np.asarray(out["total"])
    assert (totals[:-1] >= totals[1:]).all()
    ref = run_sql(
        "select n_name, count(*) as suppliers, sum(s_acctbal) as total "
        "from supplier, nation where s_nationkey = n_nationkey "
        "and s_acctbal > 0 group by n_name "
        "order by total desc limit 5", tpch_db, optimize=False)
    assert_tables_equal(out, ref)


def test_select_distinct(tpch_db):
    out = run_sql("select distinct l_returnflag from lineitem "
                  "order by l_returnflag", tpch_db)
    assert sorted(np.asarray(out["l_returnflag"]).tolist()) == ["A", "N", "R"]
