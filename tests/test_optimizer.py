"""Rule-based optimizer: per-rule units + whole-pipeline semantics.

The load-bearing invariant: ``optimize`` must be semantics-preserving on
every plan the system can express — all 22 hand-built TPC-H plans are run
through the full rule pipeline and compared row-for-row on the numpy oracle.
"""
import pytest

from repro.core.fallback import FallbackEngine
from repro.core.plan import (
    AggregateRel, FilterRel, JoinRel, ProjectRel, ReadRel, SortRel, explain,
    walk,
)
from repro.data.tpch_queries import QUERIES
from repro.optimizer import annotate, estimate, optimize, rel_columns
from repro.optimizer.rules import (
    choose_build_sides, fold_constants, order_conjuncts, prune_projections,
    pushdown_predicates, reorder_joins,
)
from repro.relational.aggregate import AggSpec
from repro.relational.expressions import BinOp, Col, Lit
from repro.sql.binder import DEFAULT_CATALOG

from conftest import assert_tables_equal

CAT = DEFAULT_CATALOG


# ---------------------------------------------------------------------------
# rule units
# ---------------------------------------------------------------------------


def test_fold_constants():
    plan = FilterRel(ReadRel("nation"),
                     BinOp("and",
                           Col("n_nationkey") < (Lit(2) + Lit(3) * Lit(4)),
                           Lit(True)))
    out = fold_constants(plan, CAT)
    cond = out.condition
    assert isinstance(cond.right, Lit) and cond.right.value == 14
    # original plan untouched (passes are pure)
    assert isinstance(plan.condition, BinOp) and plan.condition.op == "and"


def test_pushdown_through_join_to_both_sides():
    join = JoinRel(ReadRel("orders"), ReadRel("customer"),
                   ["o_custkey"], ["c_custkey"], "inner")
    pred_probe = Col("o_shippriority") == Lit(0)
    pred_build = Col("c_acctbal") > Lit(0.0)
    pred_both = Col("o_totalprice") > Col("c_acctbal")
    plan = FilterRel(FilterRel(FilterRel(join, pred_probe), pred_build),
                     pred_both)
    out = pushdown_predicates(plan, CAT)
    assert isinstance(out, JoinRel)
    assert isinstance(out.probe, ReadRel) and out.probe.filter is not None
    assert isinstance(out.build, ReadRel) and out.build.filter is not None
    assert out.post_filter is not None          # cross-side pred → residual


def test_pushdown_stops_at_left_join_build_side():
    join = JoinRel(ReadRel("customer"), ReadRel("orders"),
                   ["c_custkey"], ["o_custkey"], "left")
    plan = FilterRel(join, Col("o_totalprice") > Lit(100.0))
    out = pushdown_predicates(plan, CAT)
    assert isinstance(out, FilterRel)           # stays above the outer join
    assert out.input.build.filter is None


def test_pushdown_respects_sort_limit():
    top10 = SortRel(ReadRel("orders"), [], limit=10)
    plan = FilterRel(top10, Col("o_totalprice") > Lit(0.0))
    out = pushdown_predicates(plan, CAT)
    assert isinstance(out, FilterRel)           # limit is order-sensitive
    assert out.input.input.filter is None


def test_prune_projections_narrows_scans():
    agg = AggregateRel(ReadRel("lineitem"), ["l_returnflag"],
                       [AggSpec("sum", Col("l_quantity"), "q")])
    out = prune_projections(agg, CAT)
    assert set(out.input.columns) == {"l_returnflag", "l_quantity"}


def test_prune_keeps_join_keys():
    join = JoinRel(ReadRel("orders"), ReadRel("customer"),
                   ["o_custkey"], ["c_custkey"], "inner")
    agg = AggregateRel(join, [], [AggSpec("sum", Col("o_totalprice"), "t")])
    out = prune_projections(agg, CAT)
    assert set(out.input.probe.columns) == {"o_custkey", "o_totalprice"}
    assert out.input.build.columns == ["c_custkey"]


def test_choose_build_side_swaps_to_smaller():
    join = JoinRel(ReadRel("nation"), ReadRel("lineitem"),
                   ["n_nationkey"], ["l_suppkey"], "inner")
    out = choose_build_sides(join, CAT)
    assert out.build.table == "nation"          # 25 rows beats 6M
    assert out.probe_keys == ["l_suppkey"]
    assert out.build_keys == ["n_nationkey"]


def test_choose_build_side_leaves_asymmetric_joins():
    join = JoinRel(ReadRel("nation"), ReadRel("lineitem"),
                   ["n_nationkey"], ["l_suppkey"], "semi")
    out = choose_build_sides(join, CAT)
    assert out.build.table == "lineitem"


def test_reorder_joins_moves_selective_build_first():
    # base lineitem joins huge orders, then tiny filtered nation via suppkey
    j1 = JoinRel(ReadRel("lineitem"), ReadRel("orders"),
                 ["l_orderkey"], ["o_orderkey"], "inner")
    j2 = JoinRel(j1, ReadRel("nation", filter=Col("n_name") == Lit("PERU")),
                 ["l_suppkey"], ["n_nationkey"], "inner")
    out = reorder_joins(j2, CAT)
    assert out.build.table == "orders"          # outermost join is now orders
    assert out.probe.build.table == "nation"    # nation applied first


def test_reorder_respects_key_availability():
    # the second join's probe key comes from the first join's build side:
    # reordering must keep the dependency order
    j1 = JoinRel(ReadRel("orders"), ReadRel("customer"),
                 ["o_custkey"], ["c_custkey"], "inner")
    j2 = JoinRel(j1, ReadRel("nation"),
                 ["c_nationkey"], ["n_nationkey"], "inner")
    out = reorder_joins(j2, CAT)
    # nation's probe key (c_nationkey) needs customer joined first
    assert out.build.table == "nation"
    assert out.probe.build.table == "customer"


def test_order_conjuncts_most_selective_first():
    f = ((Col("l_quantity") < Lit(24.0))
         & (Col("l_shipmode") == Lit("MAIL")))
    plan = order_conjuncts(ReadRel("lineitem", filter=f), CAT)
    assert plan.filter.left.op == "=="          # eq (0.05) before range (0.3)


def test_estimates_and_annotation():
    scan = ReadRel("lineitem", filter=Col("l_quantity") < Lit(24.0))
    est = estimate(scan, CAT)
    assert 0 < est < CAT.row_estimate("lineitem")
    annotate(scan, CAT)
    assert "rows]" in explain(scan)


def test_rel_columns_shapes():
    join = JoinRel(ReadRel("orders", ["o_orderkey", "o_custkey"]),
                   ReadRel("customer"), ["o_custkey"], ["c_custkey"], "semi")
    assert rel_columns(join, CAT) == ["o_orderkey", "o_custkey"]
    agg = AggregateRel(join, ["o_custkey"], [AggSpec("count", None, "n")])
    assert rel_columns(agg, CAT) == ["o_custkey", "n"]


# ---------------------------------------------------------------------------
# whole-pipeline semantics on every hand-built TPC-H plan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("qid", sorted(QUERIES))
def test_optimize_preserves_semantics_q(qid, tpch_db):
    fb = FallbackEngine(tpch_db)
    ref = fb.execute(QUERIES[qid]())
    got = fb.execute(optimize(QUERIES[qid]()))
    assert_tables_equal(got, ref)


def test_optimize_is_pure(tpch_db):
    """optimize must not mutate its input plan."""
    from repro.core.plan import plan_equal
    a, b = QUERIES[3](), QUERIES[3]()
    optimize(a)
    assert plan_equal(a, b)
