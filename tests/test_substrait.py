"""Substrait-style interchange + hybrid drop-in acceleration layer.

Four contracts under test (ISSUE 5 / DESIGN.md §11):

* **serialization stability** — every TPC-H + ClickBench plan emits byte-
  identical wire against the checked-in golden files, round-trips
  emit→ingest structurally exact (``plan_equal``), and re-emits byte-stable;
* **actionable rejection** — mutated wire (unknown rel types, undeclared /
  unregistered function URIs, missing fields, version skew) fails with a
  ``SubstraitError`` carrying a document path, never a ``KeyError``;
* **hybrid routing** — fully supported plans form exactly one device
  fragment with zero in-fragment host transfers and zero boundary bytes;
  plans containing unsupported rels (WindowRel, SetRel) or capability-
  subtracted expressions degrade to hybrid execution on the fallback
  oracle with boundary transfers accounted, instead of raising;
* **the drop-in front door** — ``SiriusEngine.accelerate(wire)`` executes
  ingested plans row-exact against the SQL path on both engines.
"""
import copy
import json
import os

import numpy as np
import pytest

from repro.core import instrument
from repro.core.executor import SiriusEngine
from repro.core.fallback import FallbackEngine
from repro.core.plan import (
    AggregateRel, ExchangeRel, FetchRel, FilterRel, JoinRel, ProjectRel,
    ReadRel, ScalarSubquery, SetRel, SortRel, WindowRel, explain, plan_equal,
    plan_from_json, plan_to_json, walk_deep,
)
from repro.data.tpch_queries import SQL_QUERIES
from repro.relational.aggregate import AggSpec
from repro.relational.expressions import (
    Between, BinOp, Case, Cast, Col, DateLit, ExtractYear, InList, Like, Lit,
    StartsWith, Substr, UnOp,
)
from repro.relational.sort import SortKey
from repro.sql import run_sql, sql_to_plan, sql_to_wire
from repro.sql.binder import DEFAULT_CATALOG
from repro.substrait import (
    CapabilityRegistry, HybridRouter, SubstraitError, emit,
    explain_fragments, ingest, wire_bytes,
)

from conftest import assert_tables_equal

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden", "substrait")


def _golden(name: str) -> bytes:
    with open(os.path.join(GOLDEN_DIR, f"{name}.json"), "rb") as f:
        return f.read()


# ---------------------------------------------------------------------------
# serialization stability (golden wire files)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("qid", sorted(SQL_QUERIES))
def test_tpch_wire_golden_and_roundtrip(qid):
    plan = sql_to_plan(SQL_QUERIES[qid])
    wire = emit(plan, DEFAULT_CATALOG)
    blob = wire_bytes(wire)
    assert blob == _golden(f"tpch_q{qid}"), (
        f"q{qid}: emitted wire drifted from the golden file; if the change "
        "is intentional run scripts/substrait_smoke.py --update-golden")
    restored = ingest(wire)
    assert plan_equal(restored, plan), f"q{qid}: round-trip not exact"
    assert wire_bytes(emit(restored, DEFAULT_CATALOG)) == blob, (
        f"q{qid}: re-emission not byte-stable")


def test_clickbench_wire_golden_and_roundtrip():
    from repro.data.clickbench import CLICKBENCH_QUERIES, clickbench_catalog
    cat = clickbench_catalog()
    for qid in sorted(CLICKBENCH_QUERIES):
        plan = sql_to_plan(CLICKBENCH_QUERIES[qid], cat)
        wire = emit(plan, cat)
        blob = wire_bytes(wire)
        assert blob == _golden(f"clickbench_{qid}"), f"{qid}: wire drifted"
        restored = ingest(wire)
        assert plan_equal(restored, plan), f"{qid}: round-trip not exact"
        assert wire_bytes(emit(restored, cat)) == blob, qid


def test_wire_carries_version_extensions_and_schemas():
    wire = sql_to_wire(SQL_QUERIES[6])
    assert wire["version"]["majorNumber"] == 0
    assert wire["version"]["producer"].startswith("repro-substrait")
    names = [e["extensionFunction"]["name"] for e in wire["extensions"]]
    assert "between" in names and "and" in names and "sum" in names
    uris = {u["uri"] for u in wire["extensionUris"]}
    assert all(u.startswith("https://github.com/substrait-io/") for u in uris)
    # schema block: dtype + dictionary kinds for every scanned base table
    li = wire["schemas"]["lineitem"]["columns"]
    by_name = {c["name"]: c for c in li}
    assert by_name["l_shipdate"]["dtype"] == "date32[day]"
    assert by_name["l_returnflag"]["dictionary"] is True
    assert by_name["l_quantity"]["dictionary"] is False


# ---------------------------------------------------------------------------
# property-style round-trip over the full rel/expr vocabulary
# ---------------------------------------------------------------------------


def _synthetic_plans():
    lineitem = ReadRel("lineitem", ["l_orderkey", "l_quantity", "l_comment"])
    orders = ReadRel("orders", ["o_orderkey", "o_orderdate"],
                     filter=Between(Col("o_orderdate"),
                                    DateLit("1994-01-01"),
                                    DateLit("1994-12-31")))
    exprs = [
        UnOp("not", Like(Col("l_comment"), "%special%requests%", True)),
        InList(Col("l_orderkey"), [1, 2, 3], negate=True),
        Case([(Col("l_quantity") > 10, Lit(1.5))], Lit(0.0)),
        Cast(ExtractYear(Col("o_orderdate")), "float64"),
        Substr(Col("l_comment"), 1, 3) == Lit("abc"),
        StartsWith(Col("l_comment"), "fur"),
        Col("l_quantity") * (Lit(1) - Col("l_quantity") / Lit(7.0)),
    ]
    plans = [FilterRel(lineitem, e) for e in exprs[:2]]
    plans.append(ProjectRel(lineitem, [("v", e) for e in [exprs[2]]],
                            keep_input=True))
    plans.append(JoinRel(lineitem, orders, ["l_orderkey"], ["o_orderkey"],
                         how="mark", mark_name="__hit",
                         post_filter=Col("l_quantity") > 5))
    plans.append(AggregateRel(
        lineitem, ["l_orderkey"],
        [AggSpec("sum", Col("l_quantity"), "s"),
         AggSpec("count_star", None, "n"),
         AggSpec("count_distinct", Col("l_comment"), "d")],
        having=Col("s") > Lit(10)))
    plans.append(SortRel(FetchRel(lineitem, 100),
                         [SortKey("l_quantity", False),
                          SortKey("l_orderkey", True)], limit=7))
    plans.append(ExchangeRel(lineitem, "shuffle", ["l_orderkey"]))
    plans.append(SetRel([lineitem, ReadRel("lineitem")], "union_all"))
    plans.append(WindowRel(lineitem, ["l_orderkey"],
                           [SortKey("l_quantity", False)], "row_number",
                           None, "rn"))
    plans.append(WindowRel(lineitem, [], [], "sum", "l_quantity", "tot"))
    plans.append(FilterRel(
        lineitem,
        Col("l_quantity") > ScalarSubquery(
            AggregateRel(ReadRel("lineitem", ["l_quantity"]), [],
                         [AggSpec("avg", Col("l_quantity"), "a")]), "a")))
    return plans


@pytest.mark.parametrize("i", range(len(_synthetic_plans())))
def test_synthetic_vocabulary_roundtrip(i):
    plan = _synthetic_plans()[i]
    wire = emit(plan, DEFAULT_CATALOG)
    blob = wire_bytes(wire)
    restored = ingest(json.loads(blob.decode()))   # through real JSON text
    assert plan_equal(restored, plan)
    assert wire_bytes(emit(restored, DEFAULT_CATALOG)) == blob
    # the legacy JSON round-trip must agree on the same vocabulary
    assert plan_equal(plan_from_json(plan_to_json(plan)), plan)


def test_new_rels_have_explain_support():
    plans = _synthetic_plans()
    txt = "\n".join(explain(p) for p in plans)
    assert "SetRel union_all over 2 inputs" in txt
    assert "WindowRel row_number partition by ['l_orderkey']" in txt
    assert "order by l_quantity desc" in txt
    assert "WindowRel sum(l_quantity)" in txt


# ---------------------------------------------------------------------------
# actionable rejection of malformed wire
# ---------------------------------------------------------------------------


def _q6_wire():
    return sql_to_wire(SQL_QUERIES[6])


def test_unknown_rel_type_is_substrait_error():
    wire = _q6_wire()
    root = wire["relations"][0]["root"]["input"]
    key, body = next(iter(root.items()))
    wire["relations"][0]["root"]["input"] = {"windowagg_v2": body}
    with pytest.raises(SubstraitError) as ei:
        ingest(wire)
    msg = str(ei.value)
    assert "windowagg_v2" in msg and "read" in msg  # names the vocabulary


def test_unregistered_function_name_is_substrait_error():
    wire = _q6_wire()
    wire["extensions"][0]["extensionFunction"]["name"] = "frobnicate"
    with pytest.raises(SubstraitError) as ei:
        ingest(wire)
    assert "frobnicate" in str(ei.value)
    assert "registry" in str(ei.value)


def test_undeclared_uri_reference_is_substrait_error():
    wire = _q6_wire()
    wire["extensions"][0]["extensionFunction"]["extensionUriReference"] = 404
    with pytest.raises(SubstraitError) as ei:
        ingest(wire)
    assert "404" in str(ei.value)


def test_dangling_function_reference_is_substrait_error():
    wire = _q6_wire()

    def bump(node):
        if isinstance(node, dict):
            if "functionReference" in node:
                node["functionReference"] = 9999
                return True
            return any(bump(v) for v in node.values())
        if isinstance(node, list):
            return any(bump(v) for v in node)
        return False

    assert bump(wire["relations"])
    with pytest.raises(SubstraitError) as ei:
        ingest(wire)
    assert "9999" in str(ei.value)


def test_missing_field_is_substrait_error_with_path():
    wire = _q6_wire()

    def find_read(node):
        if isinstance(node, dict):
            if "read" in node:
                return node["read"]
            for v in node.values():
                r = find_read(v)
                if r is not None:
                    return r
        if isinstance(node, list):
            for v in node:
                r = find_read(v)
                if r is not None:
                    return r
        return None

    read = find_read(wire["relations"])
    del read["table"]
    with pytest.raises(SubstraitError) as ei:
        ingest(wire)
    assert "table" in str(ei.value)
    assert "relations[0].root.input" in str(ei.value)


def test_version_major_mismatch_rejected():
    wire = _q6_wire()
    wire["version"]["majorNumber"] = 7
    with pytest.raises(SubstraitError) as ei:
        ingest(wire)
    assert "major" in str(ei.value).lower()


def test_invalid_window_and_set_wire_rejected():
    """Semantic wire validation: shapes that would only explode at
    execution time are refused at ingest with a SubstraitError."""
    base = emit(WindowRel(ReadRel("lineitem"), [], [], "sum",
                          "l_quantity", "s"), DEFAULT_CATALOG)
    # window aggregate without an argument column
    wire = json.loads(wire_bytes(base).decode())
    wire["relations"][0]["root"]["input"]["window"]["argument"] = None
    with pytest.raises(SubstraitError) as ei:
        ingest(wire)
    assert "argument" in str(ei.value)
    # count_star is an aggregate measure, not a window function
    wire = json.loads(wire_bytes(
        emit(AggregateRel(ReadRel("lineitem"), [],
                          [AggSpec("count_star", None, "n")]),
             DEFAULT_CATALOG)).decode())
    anchor = wire["extensions"][0]["extensionFunction"]["functionAnchor"]
    wire["relations"][0]["root"]["input"] = {
        "window": {"input": {"read": {"table": "lineitem"}},
                   "partitionKeys": [], "orderKeys": [],
                   "functionReference": anchor, "argument": None,
                   "name": "n"}}
    with pytest.raises(SubstraitError) as ei:
        ingest(wire)
    assert "count_star" in str(ei.value)
    # a set relation with no inputs
    sw = json.loads(wire_bytes(
        emit(SetRel([ReadRel("lineitem")]), DEFAULT_CATALOG)).decode())
    sw["relations"][0]["root"]["input"]["set"]["inputs"] = []
    with pytest.raises(SubstraitError) as ei:
        ingest(sw)
    assert "at least one input" in str(ei.value)


def test_wrong_typed_wire_values_rejected():
    """Type confusion (not just deletion) must also stay SubstraitError."""
    wire = _q6_wire()
    wire["relations"][0] = "not an object"
    with pytest.raises(SubstraitError):
        ingest(wire)
    wire = _q6_wire()
    wire["extensions"][0] = "not an object"
    with pytest.raises(SubstraitError):
        ingest(wire)
    wire = _q6_wire()
    wire["extensionUris"] = "nope"
    with pytest.raises(SubstraitError):
        ingest(wire)


def test_garbage_inputs_rejected():
    with pytest.raises(SubstraitError):
        ingest("this is not json {")
    with pytest.raises(SubstraitError):
        ingest([1, 2, 3])
    with pytest.raises(SubstraitError):
        ingest({"relations": []})
    wire = _q6_wire()
    del wire["version"]
    with pytest.raises(SubstraitError):
        ingest(wire)


def test_mutations_never_leak_keyerror():
    """Fuzz-ish sweep: deleting any single dict key from the wire must
    produce SubstraitError (or still ingest fine for optional fields) —
    never a raw KeyError/TypeError."""
    base = wire_bytes(_q6_wire())

    def paths(node, prefix=()):
        if isinstance(node, dict):
            for k, v in node.items():
                yield prefix + (k,)
                yield from paths(v, prefix + (k,))
        elif isinstance(node, list):
            for i, v in enumerate(node):
                yield from paths(v, prefix + (i,))

    all_paths = list(paths(json.loads(base.decode())))
    for path in all_paths:
        wire = json.loads(base.decode())
        node = wire
        for p in path[:-1]:
            node = node[p]
        del node[path[-1]]
        try:
            ingest(wire)
        except SubstraitError:
            pass   # actionable rejection: exactly the contract
        except (KeyError, AttributeError, IndexError, TypeError) as e:
            raise AssertionError(
                f"deleting {'.'.join(map(str, path))} leaked "
                f"{type(e).__name__}: {e}")


# ---------------------------------------------------------------------------
# hybrid router
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("qid", [1, 6, 13])
def test_supported_queries_form_one_device_fragment(qid, tpch_engine,
                                                    tpch_db):
    wire = sql_to_wire(SQL_QUERIES[qid])
    before_h = tpch_engine.buffers.boundary_to_host_bytes
    before_d = tpch_engine.buffers.boundary_to_device_bytes
    tpch_engine.accelerate(wire)                       # warm compile caches
    with instrument.track_transfers() as counter:
        got = tpch_engine.accelerate(wire)
    report = tpch_engine.last_accelerate_report
    assert report["device_fragments"] == 1
    assert report["host_fragments"] == 0
    assert report["device_rel_fraction"] == 1.0
    assert report["boundary_to_host_bytes"] == 0
    assert report["boundary_to_device_bytes"] == 0
    assert tpch_engine.buffers.boundary_to_host_bytes == before_h
    assert tpch_engine.buffers.boundary_to_device_bytes == before_d
    assert counter.in_pipeline == 0, (
        f"q{qid}: {counter.in_pipeline} host transfers inside the device "
        "fragment")
    ref = run_sql(SQL_QUERIES[qid], tpch_db)
    assert_tables_equal(got.to_host(), ref)


def _window_plan():
    return FilterRel(
        WindowRel(ReadRel("lineitem", ["l_orderkey", "l_quantity"]),
                  ["l_orderkey"], [SortKey("l_quantity", False)],
                  "row_number", None, "rn"),
        BinOp("==", Col("rn"), Lit(1)))


def test_unsupported_rel_degrades_to_hybrid_not_raise(tpch_engine, tpch_db):
    plan = _window_plan()
    # the device engine alone cannot lower WindowRel ...
    with pytest.raises(TypeError):
        tpch_engine.execute(_window_plan())
    # ... but the drop-in path degrades to hybrid execution
    before_h = tpch_engine.buffers.boundary_to_host_bytes
    before_d = tpch_engine.buffers.boundary_to_device_bytes
    got = tpch_engine.accelerate(emit(plan, DEFAULT_CATALOG))
    report = tpch_engine.last_accelerate_report
    assert report["host_fragments"] == 1
    assert report["device_fragments"] == 2      # scan below + filter above
    assert 0 < report["device_rel_fraction"] < 1
    # boundary transfers are accounted on the buffer manager
    assert report["boundary_to_host_bytes"] > 0
    assert report["boundary_to_device_bytes"] > 0
    assert tpch_engine.buffers.boundary_to_host_bytes \
        == before_h + report["boundary_to_host_bytes"]
    assert tpch_engine.buffers.boundary_to_device_bytes \
        == before_d + report["boundary_to_device_bytes"]
    # row-exact vs the pure-host oracle executing the identical plan
    ref = FallbackEngine(tpch_db).execute(_window_plan())
    assert_tables_equal(got.to_host(), ref)


def test_setrel_union_all_hybrid(tpch_engine, tpch_db):
    half1 = ReadRel("orders", ["o_orderkey", "o_totalprice"],
                    filter=Col("o_orderkey") <= Lit(1000))
    half2 = ReadRel("orders", ["o_orderkey", "o_totalprice"],
                    filter=Col("o_orderkey") > Lit(1000))
    plan = AggregateRel(SetRel([half1, half2]), [],
                        [AggSpec("count_star", None, "n"),
                         AggSpec("sum", Col("o_totalprice"), "s")])
    got = tpch_engine.accelerate(emit(plan, DEFAULT_CATALOG))
    report = tpch_engine.last_accelerate_report
    assert report["host_fragments"] == 1        # the SetRel itself
    assert report["device_fragments"] == 3      # two scans + the aggregate
    ref = FallbackEngine(tpch_db).execute(copy.deepcopy(plan))
    assert_tables_equal(got.to_host(), ref)


def test_per_expr_capability_subtraction_routes_to_host(tpch_engine, tpch_db):
    """An engine that lacks LIKE must degrade the containing rel to the
    host fragment — the per-expr half of the capability table."""
    registry = CapabilityRegistry(host_only_exprs=["Like"])
    sql = SQL_QUERIES[13]                       # LIKE lives in a join build
    plan = sql_to_plan(sql)
    assert any(isinstance(e, Like)
               for r in walk_deep(plan)
               for e in _all_exprs(r)), "q13 lost its LIKE predicate"
    got = tpch_engine.accelerate(sql_to_wire(sql), registry=registry)
    report = tpch_engine.last_accelerate_report
    assert report["host_fragments"] >= 1
    assert report["device_rel_fraction"] < 1.0
    ref = run_sql(sql, tpch_db)
    assert_tables_equal(got.to_host(), ref)


def _all_exprs(rel):
    from repro.core.plan import rel_exprs
    from repro.relational.expressions import walk_expr
    for e in rel_exprs(rel):
        yield from walk_expr(e)


def test_fragment_planning_is_pure_and_explainable(tpch_engine):
    router = HybridRouter(tpch_engine)
    frags = router.plan_fragments(_window_plan())
    assert [f.placement for f in frags] == ["device", "host", "device"]
    assert frags[1].deps == [0] and frags[2].deps == [1]
    assert router.device_fragment_fraction(_window_plan()) == \
        pytest.approx(2 / 3)
    txt = explain_fragments(frags)
    assert "Fragment 0 [device]" in txt
    assert "Fragment 1 [host] deps=[0]" in txt
    assert "[hybrid boundary]" in txt
    # pure device plan: fraction 1.0, single fragment
    q6 = sql_to_plan(SQL_QUERIES[6])
    assert router.device_fragment_fraction(q6) == 1.0
    assert len(router.plan_fragments(q6)) == 1


def test_host_rooted_plan_accounts_result_conversion(tpch_engine, tpch_db):
    """When the root fragment itself runs on the host, the result's trip
    back to device is a boundary crossing and must be accounted."""
    plan = WindowRel(ReadRel("lineitem", ["l_orderkey", "l_quantity"]),
                     ["l_orderkey"], [], "sum", "l_quantity", "s")
    before = tpch_engine.buffers.boundary_to_device_bytes
    got = tpch_engine.accelerate(emit(plan, DEFAULT_CATALOG))
    report = tpch_engine.last_accelerate_report
    assert report["fragments"][-1]["placement"] == "host"
    assert report["boundary_to_device_bytes"] > 0
    assert tpch_engine.buffers.boundary_to_device_bytes \
        == before + report["boundary_to_device_bytes"]
    ref = FallbackEngine(tpch_db).execute(
        WindowRel(ReadRel("lineitem", ["l_orderkey", "l_quantity"]),
                  ["l_orderkey"], [], "sum", "l_quantity", "s"))
    assert_tables_equal(got.to_host(), ref)


def test_window_oracle_semantics():
    """WindowRel numpy semantics sanity: row_number + partition aggregate."""
    db = {"t": {"g": np.array([1, 1, 2, 2, 2]),
                "v": np.array([3.0, 1.0, 5.0, 4.0, 6.0])}}
    fb = FallbackEngine(db)
    rn = fb.execute(WindowRel(ReadRel("t"), ["g"],
                              [SortKey("v", True)], "row_number", None, "rn"))
    assert list(rn["rn"]) == [2, 1, 2, 1, 3]
    tot = fb.execute(WindowRel(ReadRel("t"), ["g"], [], "sum", "v", "s"))
    assert list(tot["s"]) == [4.0, 4.0, 15.0, 15.0, 15.0]
    avg = fb.execute(WindowRel(ReadRel("t"), [], [], "avg", "v", "a"))
    np.testing.assert_allclose(avg["a"], np.full(5, 19.0 / 5))


# ---------------------------------------------------------------------------
# ingested plans execute row-exact vs the SQL path (acceptance sweep)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("qid", sorted(SQL_QUERIES))
def test_ingested_golden_executes_row_exact(qid, tpch_engine, tpch_db):
    """The full drop-in loop at query scale: checked-in golden wire →
    ingest → accelerate, vs the SQL path on the numpy oracle."""
    blob = _golden(f"tpch_q{qid}")
    ref = run_sql(SQL_QUERIES[qid], tpch_db)          # SQL path, oracle
    got = tpch_engine.accelerate(blob)                # wire path, engine
    assert tpch_engine.last_accelerate_report["device_rel_fraction"] == 1.0
    assert_tables_equal(got.to_host(), ref)
    # wire path on the oracle as well: ingest once more (execution mutates
    # scalar-subquery exprs), run on the host engine
    host = FallbackEngine(tpch_db).execute(ingest(blob))
    assert_tables_equal(host, ref)
