"""Optional-hypothesis shim.

`hypothesis` is a dev-only dependency (requirements-dev.txt).  When it is
not installed, the property-based tests are skipped individually and the
rest of the module still collects and runs.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stand-in for ``hypothesis.strategies`` — never actually drawn."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def settings(*_a, **_k):
        return lambda fn: fn

    def given(*_a, **_k):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed "
                                     "(see requirements-dev.txt)")
            def _skipped(*args, **kwargs):
                pass

            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            return _skipped
        return deco
