"""Serve a small model with batched requests: prefill + decode loop.

Demonstrates the serving substrate the decode_32k / long_500k dry-run shapes
lower: batched KV cache, per-sequence lengths (ragged batch), greedy decode.
Uses the jamba-family reduced config so the cache carries all three state
kinds (attention KV, Mamba conv/ssm) in one server.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.models import lm


def main():
    cfg = reduced(get_config("jamba-v0.1-52b"))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)

    # a batch of requests with ragged prompt lengths
    batch = 4
    max_cache = 96
    prompt_lens = [5, 9, 3, 7]
    prompts = [rng.integers(0, cfg.vocab, n) for n in prompt_lens]

    cache = lm.init_cache(cfg, batch, max_cache)
    decode = jax.jit(lambda p, c, t: lm.decode_step(p, cfg, c, t))

    # prefill via sequential decode steps (teacher forcing the prompt);
    # ragged lengths handled by feeding pad tokens and masking the output
    t0 = time.time()
    maxp = max(prompt_lens)
    last_logits = None
    for i in range(maxp):
        toks = np.array([[p[i] if i < len(p) else 0] for p in prompts],
                        np.int32)
        last_logits, cache = decode(params, cache, jnp.asarray(toks))
    print(f"prefill: {maxp} steps × {batch} seqs in {time.time()-t0:.2f}s "
          f"(cache length now {np.asarray(cache['length'])})")

    # greedy decode 16 new tokens per sequence
    out_tokens = [[] for _ in range(batch)]
    tok = jnp.argmax(last_logits[..., : cfg.vocab], axis=-1).astype(jnp.int32)
    t0 = time.time()
    n_new = 16
    for _ in range(n_new):
        for b in range(batch):
            out_tokens[b].append(int(tok[b, 0]))
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits[..., : cfg.vocab], axis=-1).astype(jnp.int32)
    dt = time.time() - t0
    print(f"decode: {n_new} tokens × {batch} seqs in {dt:.2f}s "
          f"({batch*n_new/dt:.1f} tok/s on CPU)")
    for b in range(batch):
        print(f"  seq{b} (prompt {prompt_lens[b]} toks) → {out_tokens[b]}")
    assert all(np.isfinite(np.asarray(last_logits, np.float32)).all()
               for _ in [0])
    print("OK")


if __name__ == "__main__":
    main()
