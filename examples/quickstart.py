"""Quickstart: drop-in accelerated SQL over the Substrait-like plan IR.

Mirrors the paper's single-node lifecycle (§3.3): the 'host database layer'
(here: hand-built plans standing in for DuckDB's optimizer, serialized
through the JSON plan format) hands the engine a plan; the engine executes it
entirely on the accelerator path with the buffer manager's cached tables, and
falls back to the host engine when something is unsupported.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.executor import SiriusEngine
from repro.core.plan import (
    AggregateRel, JoinRel, ReadRel, SortRel, plan_from_json, plan_to_json,
)
from repro.data.tpch import generate, load_into_engine
from repro.data.tpch_queries import QUERIES
from repro.relational import AggSpec, Col, Lit, SortKey, Table


def main():
    print("== generating TPC-H (SF 0.01) and cold-loading the cache ==")
    db = generate(0.01)
    engine = SiriusEngine(use_kernels=True)
    load_into_engine(engine, db)
    print("buffer manager:", engine.buffers.stats()["cached_tables"])

    print("\n== a hand-built plan crossing the Substrait boundary ==")
    plan = SortRel(
        AggregateRel(
            JoinRel(ReadRel("orders"), ReadRel("customer"),
                    ["o_custkey"], ["c_custkey"], "inner"),
            ["c_mktsegment"],
            [AggSpec("sum", Col("o_totalprice"), "revenue"),
             AggSpec("count_star", None, "orders")]),
        [SortKey("revenue", ascending=False)])
    wire = plan_to_json(plan)           # host DB → engine handoff
    result = engine.execute(plan_from_json(wire))
    for row in result.to_pylist():
        print(f"  {row['c_mktsegment']:<12} revenue={row['revenue']:'>14,.2f} "
              f"orders={row['orders']}")

    print("\n== TPC-H Q3 through the same engine ==")
    q3 = engine.execute(QUERIES[3]())
    print(q3.to_host())

    print("\n== kernel backend usage ==")
    print(f"Pallas filter kernel hits: {engine.backend.filter_hits}, "
          f"probe kernel hits: {engine.backend.probe_hits}")

    print("\n== graceful fallback (§3.2.2) ==")
    engine.host_tables["mystery"] = {"x": np.arange(4.0)}
    from repro.relational.expressions import Col as C
    bad = AggregateRel(ReadRel("mystery"), [], [AggSpec("sum", C("x"), "s")])
    res, path = engine.execute_with_fallback(bad)
    print(f"executed on: {path}; result={res['s'][0]}")


if __name__ == "__main__":
    main()
