"""Quickstart: drop-in accelerated SQL, from SQL text to device results.

Mirrors the paper's single-node lifecycle (§3.3) end to end, with the SQL
frontend as the primary path: SQL text is parsed, bound against the TPC-H
catalog, lowered to the Substrait-like plan IR, rewritten by the rule-based
optimizer (predicate pushdown, projection pruning, join ordering, build-side
selection), serialized across the host-DB → engine boundary, and executed on
the accelerator path with the buffer manager's cached tables.  Hand-built
plans remain as the fallback/oracle path — pre-optimized trees standing in
for DuckDB's output — and the engine degrades to the numpy host engine when
something is unsupported.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import time

import numpy as np

from repro.core.executor import SiriusEngine
from repro.core.plan import (
    AggregateRel, JoinRel, ReadRel, SortRel, explain, plan_from_json,
    plan_to_json,
)
from repro.data.tpch import generate, load_into_engine
from repro.data.tpch_queries import QUERIES, SQL_QUERIES
from repro.relational import AggSpec, Col, SortKey
from repro.sql import sql_to_plan


def main():
    print("== generating TPC-H (SF 0.01) and cold-loading the cache ==")
    db = generate(0.01)
    engine = SiriusEngine(use_kernels=True)
    load_into_engine(engine, db)
    print("buffer manager:", engine.buffers.stats()["cached_tables"])

    print("\n== the primary path: SQL text in, device table out ==")
    sql = """
        select c_mktsegment, sum(o_totalprice) as revenue,
               count(*) as orders
        from orders, customer
        where o_custkey = c_custkey and o_totalprice > 0
        group by c_mktsegment
        order by revenue desc
    """
    result = engine.sql(sql)
    for row in result.to_pylist():
        print(f"  {row['c_mktsegment']:<12} revenue={row['revenue']:>14,.2f} "
              f"orders={row['orders']}")

    print("\n== what the optimizer did (EXPLAIN, with row estimates) ==")
    naive = sql_to_plan(sql, optimize=False)
    optimized = sql_to_plan(sql, optimize=True)
    print("naive plan:")
    print(explain(naive))
    print("optimized plan (filters at scans, pruned reads, build sides):")
    print(explain(optimized))

    print("\n== the plan crosses the Substrait-like wire boundary ==")
    wire = plan_to_json(optimized)          # host DB → engine handoff
    print(f"wire format: {len(wire)} bytes of JSON")
    engine.execute(plan_from_json(wire))

    print("\n== TPC-H Q3: SQL text vs the hand-built oracle plan ==")
    q3_sql = engine.sql(SQL_QUERIES[3]).to_host()
    q3_oracle = engine.execute(QUERIES[3]()).to_host()
    same = all(
        np.allclose(q3_sql[k].astype(float), q3_oracle[k].astype(float))
        if np.asarray(q3_sql[k]).dtype.kind == "f"
        else (np.asarray(q3_sql[k]) == np.asarray(q3_oracle[k])).all()
        for k in q3_sql)
    print(f"rows: {len(q3_sql['l_orderkey'])}, "
          f"SQL path == hand-built plan: {same}")

    print("\n== hand-built plans still work (the fallback/oracle path) ==")
    plan = SortRel(
        AggregateRel(
            JoinRel(ReadRel("orders"), ReadRel("customer"),
                    ["o_custkey"], ["c_custkey"], "inner"),
            ["c_mktsegment"],
            [AggSpec("sum", Col("o_totalprice"), "revenue")]),
        [SortKey("revenue", ascending=False)])
    print(engine.execute(plan).to_host()["revenue"])

    print("\n== compiled pipelines: SiriusEngine(use_kernels=True) timings ==")
    # first run of a query shape traces + compiles its fused regions; repeat
    # runs replay the cached XLA programs and dispatch asynchronously,
    # syncing once per pipeline sink
    t0 = time.perf_counter()
    engine.sql(SQL_QUERIES[6])
    cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(3):
        engine.sql(SQL_QUERIES[6])
    hot = (time.perf_counter() - t0) / 3
    s = engine.compiler.stats
    print(f"Q6 cold (trace+compile): {cold*1e3:.1f} ms   "
          f"hot (cached regions): {hot*1e3:.1f} ms")
    print(f"compiled regions: {len(engine.compiler.cache)}, "
          f"traces: {s['traces']}, cache hits: {s['cache_hits']}, "
          f"fused probes: {s['fused_probes']}")

    print("\n== kernel backend usage ==")
    print(f"Pallas filter kernel hits: {engine.backend.filter_hits}, "
          f"probe kernel hits: {engine.backend.probe_hits}, "
          f"MXU aggregation hits: {engine.backend.agg_hits}")

    print("\n== graceful fallback (§3.2.2) ==")
    engine.host_tables["mystery"] = {"x": np.arange(4.0)}
    from repro.relational.expressions import Col as C
    bad = AggregateRel(ReadRel("mystery"), [], [AggSpec("sum", C("x"), "s")])
    res, path = engine.execute_with_fallback(bad)
    print(f"executed on: {path}; result={res['s'][0]}")


if __name__ == "__main__":
    main()
