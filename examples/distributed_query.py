"""Distributed TPC-H with fault injection — the paper's §3.3 'Distributed'
lifecycle plus the §3.4 fault-tolerance roadmap, runnable on forced host
devices.

Spawns itself with 8 devices, runs Q1/Q3/Q6/Q12 with the Table-2 timing
breakdown, then kills a node mid-query and shows elastic recovery.

Run:  PYTHONPATH=src python examples/distributed_query.py
"""
import os
import subprocess
import sys

INNER = os.environ.get("REPRO_DIST_INNER") == "1"

if not INNER:
    env = dict(os.environ)
    env["REPRO_DIST_INNER"] = "1"
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    raise SystemExit(subprocess.call([sys.executable, __file__], env=env))

import numpy as np  # noqa: E402

from repro.core.distributed import DistributedEngine  # noqa: E402
from repro.core.fallback import FallbackEngine  # noqa: E402
from repro.data.tpch import generate  # noqa: E402
from repro.data.tpch_queries import QUERIES  # noqa: E402
from repro.runtime.control import FaultInjector, FaultPlan  # noqa: E402


def main():
    db = generate(0.005)
    fb = FallbackEngine(db)
    print(f"== distributed TPC-H on {8} shards ==")
    eng = DistributedEngine(db, n_shards=8)
    for qid in (1, 3, 6, 12):
        got = eng.run_query(qid)
        t = eng.timers
        ref = fb.execute(QUERIES[qid]())
        n = len(next(iter(got.values())))
        print(f"Q{qid:2d}: rows={n:3d}  compute={t['compute']*1e3:7.1f}ms  "
              f"exchange={t['exchange']*1e3:7.1f}ms  "
              f"other={t['other']*1e3:7.1f}ms")
        k = next(iter(ref))
        assert len(ref[k]) == n, f"row count mismatch vs oracle on Q{qid}"

    print("\n== node failure → elastic recovery (§3.4, implemented) ==")
    inj = FaultInjector([FaultPlan(fragment="q3_join", node=5, times=1)])
    eng2 = DistributedEngine(db, n_shards=8, injector=inj)
    got = eng2.run_query(3)
    ref = fb.execute(QUERIES[3]())
    same = np.allclose(np.asarray(got["revenue"], float),
                       np.asarray(ref["revenue"], float))
    print(f"node 5 killed during q3_join → recovered on "
          f"{eng2.n_shards} shards; result identical: {same}")
    print(f"recoveries={eng2.recoveries}, "
          f"live nodes={eng2.heartbeat.live_nodes()}")


if __name__ == "__main__":
    main()
