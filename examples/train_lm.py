"""End-to-end driver: train a ~100M-param qwen3-family model for N steps.

Uses the same config system, model code, optimizer and checkpointing the
production mesh uses — scaled to a CPU-runnable width.  The loss must drop;
a checkpoint is written and restored to prove restart-consistency.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import lm
from repro.runtime.checkpoint import load_npz, save_npz
from repro.training.optimizer import OptConfig, init_opt_state
from repro.training.train_step import make_train_step


def make_cfg():
    # ~100M-param sibling of qwen3-4b (same family: GQA + qk_norm + swiglu)
    base = get_config("qwen3-4b")
    return dataclasses.replace(
        base, n_layers=6, d_model=384, n_heads=6, n_kv_heads=2, head_dim=64,
        d_ff=1536, vocab=2048, dtype="float32")


def synthetic_stream(vocab: int, batch: int, seq: int, seed=0):
    """Markov-ish token stream: learnable structure, not pure noise."""
    rng = np.random.default_rng(seed)
    trans = rng.integers(0, vocab, size=(vocab, 2))
    state = rng.integers(0, vocab, size=(batch,))
    while True:
        toks = np.empty((batch, seq + 1), np.int32)
        toks[:, 0] = state
        for i in range(1, seq + 1):
            pick = rng.integers(0, 2, size=batch)
            noise = rng.random(batch) < 0.05
            nxt = trans[toks[:, i - 1], pick]
            toks[:, i] = np.where(noise, rng.integers(0, vocab, batch), nxt)
        state = toks[:, -1]
        yield {"tokens": jnp.asarray(toks[:, :-1]),
               "targets": jnp.asarray(toks[:, 1:])}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_train_ckpt.npz")
    args = ap.parse_args()

    cfg = make_cfg()
    n_params = cfg.param_count()
    print(f"model: {cfg.name}-mini  params={n_params/1e6:.1f}M")

    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    state = {"params": params, "opt": init_opt_state(params)}
    step_fn = jax.jit(make_train_step(
        cfg, OptConfig(lr=3e-3, warmup_steps=10, total_steps=args.steps)))

    stream = synthetic_stream(cfg.vocab, args.batch, args.seq)
    first = last = None
    t0 = time.time()
    for step in range(1, args.steps + 1):
        state, metrics = step_fn(state, next(stream))
        loss = float(metrics["loss"])
        first = first if first is not None else loss
        last = loss
        if step % 20 == 0 or step == 1:
            print(f"step {step:4d}  loss {loss:.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.2f}  "
                  f"{(time.time()-t0)/step:.2f}s/step")

    print(f"loss {first:.3f} → {last:.3f} "
          f"({'IMPROVED' if last < first - 0.2 else 'no improvement!'})")

    # checkpoint → restore → one more step must be reproducible
    flat = {f"p{i}": np.asarray(x)
            for i, x in enumerate(jax.tree.leaves(state["params"]))}
    save_npz(args.ckpt, flat, manifest={"step": args.steps})
    arrays, manifest = load_npz(args.ckpt)
    restored = jax.tree.unflatten(
        jax.tree.structure(state["params"]),
        [jnp.asarray(arrays[f"p{i}"]) for i in range(len(arrays))])
    diff = max(float(jnp.abs(a - b).max()) for a, b in
               zip(jax.tree.leaves(restored), jax.tree.leaves(state["params"])))
    print(f"checkpoint round-trip @step {manifest['step']}: max|Δ|={diff}")


if __name__ == "__main__":
    main()
